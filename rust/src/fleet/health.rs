//! Replica health tracking: the Healthy → Lagging → Suspect → Dead
//! state machine and the lock-free [`HealthBoard`] serving-side
//! routing reads from.
//!
//! Two signals drive the machine, both measured in publish rounds
//! (the fabric's clock):
//!
//! * **heartbeat age** — consecutive rounds without a successful
//!   contact (delivery, retry, or recovery probe).  Crossing
//!   `suspect_after` demotes to Suspect, `dead_after` to Dead.
//! * **seq lag** — `head - replica_seq` for a replica that *is*
//!   contactable.  Lag at or past `lagging_after` marks it Lagging
//!   (still serving, but behind).
//!
//! A successful contact resets the heartbeat age, so a healed
//! partition resurrects even a Dead replica — the fabric's recovery
//! probe plus catch-up brings it back to Healthy in one round.
//! Suspect and Dead replicas are skipped by `FleetFabric::publish`
//! (no WAN bytes wasted on a black hole) and by serving-side routing
//! ([`HealthBoard::route`]), instead of stalling traffic on them.

use std::sync::atomic::{AtomicU8, Ordering};

/// One replica's health, ordered by severity.  The `u8` encoding is
/// what the `fw_fleet_replica_health` gauge exports (0=healthy,
/// 1=lagging, 2=suspect, 3=dead).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum HealthState {
    Healthy,
    Lagging,
    Suspect,
    Dead,
}

impl HealthState {
    pub fn as_gauge(self) -> u8 {
        match self {
            HealthState::Healthy => 0,
            HealthState::Lagging => 1,
            HealthState::Suspect => 2,
            HealthState::Dead => 3,
        }
    }

    pub fn from_gauge(v: u8) -> HealthState {
        match v {
            0 => HealthState::Healthy,
            1 => HealthState::Lagging,
            2 => HealthState::Suspect,
            _ => HealthState::Dead,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Lagging => "lagging",
            HealthState::Suspect => "suspect",
            HealthState::Dead => "dead",
        }
    }

    /// Whether traffic should still be routed here.  Lagging replicas
    /// serve (stale-but-consistent is the fleet's normal state);
    /// Suspect/Dead are routed around.
    pub fn serving(self) -> bool {
        matches!(self, HealthState::Healthy | HealthState::Lagging)
    }
}

/// Thresholds of the health machine, in publish rounds.
#[derive(Clone, Copy, Debug)]
pub struct HealthPolicy {
    /// Seq lag at which a contactable replica is marked Lagging.
    pub lagging_after: u64,
    /// Consecutive contact failures before Suspect (stop publishing
    /// to it; recovery probes take over).
    pub suspect_after: u32,
    /// Consecutive contact failures before Dead.
    pub dead_after: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy { lagging_after: 1, suspect_after: 2, dead_after: 4 }
    }
}

/// Fabric-side per-replica tracker: folds the round's contact outcome
/// and observed lag into the state machine.
#[derive(Clone, Copy, Debug)]
pub struct HealthTracker {
    state: HealthState,
    /// Heartbeat age: consecutive rounds without successful contact.
    failed_rounds: u32,
}

impl Default for HealthTracker {
    fn default() -> Self {
        HealthTracker { state: HealthState::Healthy, failed_rounds: 0 }
    }
}

impl HealthTracker {
    pub fn state(&self) -> HealthState {
        self.state
    }

    pub fn failed_rounds(&self) -> u32 {
        self.failed_rounds
    }

    /// Rebuild from checkpointed fields.
    pub fn restore(state: HealthState, failed_rounds: u32) -> Self {
        HealthTracker { state, failed_rounds }
    }

    /// Fold one round's observation: whether the replica was
    /// successfully contacted, and its seq lag afterwards.  Returns
    /// the `(from, to)` transition when the state changed.
    pub fn observe(
        &mut self,
        contacted: bool,
        lag: u64,
        policy: &HealthPolicy,
    ) -> Option<(HealthState, HealthState)> {
        if contacted {
            self.failed_rounds = 0;
        } else {
            self.failed_rounds = self.failed_rounds.saturating_add(1);
        }
        let next = if self.failed_rounds >= policy.dead_after {
            HealthState::Dead
        } else if self.failed_rounds >= policy.suspect_after {
            HealthState::Suspect
        } else if lag >= policy.lagging_after {
            HealthState::Lagging
        } else {
            HealthState::Healthy
        };
        if next != self.state {
            let from = self.state;
            self.state = next;
            Some((from, next))
        } else {
            None
        }
    }
}

/// Shared, lock-free view of every replica's health for concurrent
/// readers (traffic drivers route through it while the fabric
/// publishes).  One `AtomicU8` per replica, flattened DC-major like
/// the fabric's replica order.
#[derive(Debug)]
pub struct HealthBoard {
    states: Vec<AtomicU8>,
}

impl HealthBoard {
    pub fn new(replicas: usize) -> Self {
        HealthBoard {
            states: (0..replicas).map(|_| AtomicU8::new(0)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    pub fn get(&self, idx: usize) -> HealthState {
        // ordering: Acquire pairs with the Release in `set` — a router
        // that observes Serving also observes the replica state
        // transitions (restart, resync) that preceded the flip, so it
        // never routes to an engine still mid-recovery.
        HealthState::from_gauge(self.states[idx].load(Ordering::Acquire))
    }

    pub fn set(&self, idx: usize, state: HealthState) {
        // ordering: Release pairs with the Acquire in `get`/`route`
        // (see `get`).
        self.states[idx].store(state.as_gauge(), Ordering::Release);
    }

    /// Serving-side model resolution: the first serving replica
    /// scanning from `hint` (wrapping).  Falls back to `hint` itself
    /// when the whole fleet is unhealthy — serving stale beats
    /// serving nothing.
    pub fn route(&self, hint: usize) -> usize {
        let n = self.states.len();
        if n == 0 {
            return hint;
        }
        for off in 0..n {
            let idx = (hint + off) % n;
            if self.get(idx).serving() {
                return idx;
            }
        }
        hint % n
    }

    /// Replicas currently eligible for traffic.
    pub fn serving_count(&self) -> usize {
        (0..self.states.len()).filter(|&i| self.get(i).serving()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_encoding_roundtrip() {
        for s in [
            HealthState::Healthy,
            HealthState::Lagging,
            HealthState::Suspect,
            HealthState::Dead,
        ] {
            assert_eq!(HealthState::from_gauge(s.as_gauge()), s);
        }
        assert!(HealthState::Healthy.serving());
        assert!(HealthState::Lagging.serving());
        assert!(!HealthState::Suspect.serving());
        assert!(!HealthState::Dead.serving());
    }

    #[test]
    fn tracker_walks_the_ladder_and_heals() {
        let policy = HealthPolicy::default();
        let mut t = HealthTracker::default();
        // lag while contactable → Lagging
        assert_eq!(
            t.observe(true, 1, &policy),
            Some((HealthState::Healthy, HealthState::Lagging))
        );
        // caught up → Healthy
        assert_eq!(
            t.observe(true, 0, &policy),
            Some((HealthState::Lagging, HealthState::Healthy))
        );
        // consecutive failures: 1 keeps (lag marks Lagging), 2 → Suspect
        assert_eq!(
            t.observe(false, 1, &policy),
            Some((HealthState::Healthy, HealthState::Lagging))
        );
        assert_eq!(
            t.observe(false, 2, &policy),
            Some((HealthState::Lagging, HealthState::Suspect))
        );
        assert_eq!(t.observe(false, 3, &policy), None);
        // 4th failure → Dead
        assert_eq!(
            t.observe(false, 4, &policy),
            Some((HealthState::Suspect, HealthState::Dead))
        );
        // one successful contact resurrects straight to Healthy
        assert_eq!(
            t.observe(true, 0, &policy),
            Some((HealthState::Dead, HealthState::Healthy))
        );
        assert_eq!(t.failed_rounds(), 0);
    }

    #[test]
    fn board_routes_around_unhealthy_replicas() {
        let board = HealthBoard::new(4);
        assert_eq!(board.route(2), 2);
        board.set(2, HealthState::Suspect);
        assert_eq!(board.route(2), 3);
        board.set(3, HealthState::Dead);
        assert_eq!(board.route(2), 0);
        assert_eq!(board.serving_count(), 2);
        // whole fleet down: fall back to the hint rather than stall
        for i in 0..4 {
            board.set(i, HealthState::Dead);
        }
        assert_eq!(board.route(2), 2);
        // healed replica becomes routable again
        board.set(1, HealthState::Lagging);
        assert_eq!(board.route(2), 1);
    }
}
