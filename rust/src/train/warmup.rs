//! §4.1 — model warm-up: "a phase in model training where the model
//! starts with past data and catches up with present data as fast as
//! possible", accelerated by (a) asynchronous data prefetching and
//! (b) Hogwild multithreading (§4.2).
//!
//! The driver consumes a [`DataSource`] (historical data), optionally
//! through a [`Prefetcher`], optionally spreading each chunk across
//! Hogwild threads — the four combinations benchmarked in Table 2.

use std::time::Instant;

use crate::data::prefetch::Prefetcher;
use crate::data::DataSource;
use crate::model::regressor::Regressor;
use crate::train::hogwild::{train_chunk, HogwildConfig};
use crate::train::Trainer;

/// Warm-up strategy knobs.
#[derive(Clone, Copy, Debug)]
pub struct WarmupConfig {
    /// Examples per chunk ("round of future data").
    pub chunk_size: usize,
    /// Prefetch queue depth; 0 = synchronous (control arm).
    pub prefetch_depth: usize,
    /// Hogwild threads; 1 = sequential (control arm).
    pub threads: usize,
    /// Total examples to replay.
    pub total: usize,
}

impl Default for WarmupConfig {
    fn default() -> Self {
        WarmupConfig {
            chunk_size: 4096,
            prefetch_depth: 4,
            threads: 1,
            total: 100_000,
        }
    }
}

/// Warm-up outcome.
#[derive(Clone, Debug)]
pub struct WarmupReport {
    pub examples: usize,
    pub wall_seconds: f64,
    pub chunks: usize,
}

/// Run the warm-up phase over `source`.
pub fn warmup<S: DataSource + 'static>(
    reg: &mut Regressor,
    source: S,
    cfg: WarmupConfig,
) -> WarmupReport {
    let start = Instant::now();
    let mut chunks = 0usize;
    let mut examples = 0usize;
    let hw = HogwildConfig { threads: cfg.threads.max(1) };

    let mut learn_chunk = |reg: &mut Regressor, chunk: &[crate::feature::Example]| {
        if cfg.threads > 1 {
            train_chunk(reg, chunk, hw, usize::MAX);
        } else {
            // fast sequential path without eval overhead
            let mut ws = crate::model::Workspace::new();
            for ex in chunk {
                reg.learn(ex, &mut ws);
            }
        }
    };

    if cfg.prefetch_depth > 0 {
        let mut pf = Prefetcher::spawn(
            source,
            cfg.chunk_size,
            cfg.prefetch_depth,
            Some(cfg.total),
        );
        while let Some(chunk) = pf.next_chunk() {
            examples += chunk.len();
            chunks += 1;
            learn_chunk(reg, &chunk);
        }
    } else {
        let mut source = source;
        let mut remaining = cfg.total;
        while remaining > 0 {
            let want = cfg.chunk_size.min(remaining);
            let mut chunk = Vec::with_capacity(want);
            let got = source.next_chunk(want, &mut chunk);
            if got == 0 {
                break;
            }
            remaining -= got;
            examples += got;
            chunks += 1;
            learn_chunk(reg, &chunk);
        }
    }

    WarmupReport {
        examples,
        wall_seconds: start.elapsed().as_secs_f64(),
        chunks,
    }
}

/// Convenience: warm up then wrap in a [`Trainer`] for online rounds.
pub fn warmup_into_trainer<S: DataSource + 'static>(
    reg: Regressor,
    source: S,
    cfg: WarmupConfig,
) -> (Trainer, WarmupReport) {
    let mut reg = reg;
    let report = warmup(&mut reg, source, cfg);
    (Trainer::new(reg), report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::prefetch::DelayedSource;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};

    #[test]
    fn warmup_consumes_exactly_total() {
        let cfg = ModelConfig::ffm(4, 2, 256);
        let mut reg = Regressor::new(&cfg);
        let src = SyntheticStream::with_buckets(DatasetSpec::tiny(), 3, 256);
        let rep = warmup(
            &mut reg,
            src,
            WarmupConfig { chunk_size: 1000, prefetch_depth: 2, threads: 1, total: 5500 },
        );
        assert_eq!(rep.examples, 5500);
        assert_eq!(rep.chunks, 6);
    }

    #[test]
    fn synchronous_and_prefetched_same_model() {
        // With a deterministic source and 1 thread, prefetching must not
        // change the learned weights — only the wall time.
        let cfg = ModelConfig::ffm(4, 2, 256);
        let mk = || SyntheticStream::with_buckets(DatasetSpec::tiny(), 4, 256);
        let mut a = Regressor::new(&cfg);
        warmup(
            &mut a,
            mk(),
            WarmupConfig { chunk_size: 512, prefetch_depth: 0, threads: 1, total: 4000 },
        );
        let mut b = Regressor::new(&cfg);
        warmup(
            &mut b,
            mk(),
            WarmupConfig { chunk_size: 512, prefetch_depth: 4, threads: 1, total: 4000 },
        );
        assert_eq!(a.pool.weights, b.pool.weights);
    }

    #[test]
    fn prefetch_hides_source_latency() {
        // Per-chunk compute (DeepFFM training) exceeds the per-chunk
        // "download" sleep, so prefetching hides nearly all the sleep
        // even on a single-core host (the sleep needs no CPU).
        let cfg = ModelConfig::deep_ffm(4, 2, 256, &[16]);
        let delay = std::time::Duration::from_millis(10);
        let total = 8000;
        let mk = || {
            DelayedSource::new(
                SyntheticStream::with_buckets(DatasetSpec::tiny(), 5, 256),
                delay,
            )
        };
        let mut a = Regressor::new(&cfg);
        let sync = warmup(
            &mut a,
            mk(),
            WarmupConfig { chunk_size: 500, prefetch_depth: 0, threads: 1, total },
        );
        let mut b = Regressor::new(&cfg);
        let pre = warmup(
            &mut b,
            mk(),
            WarmupConfig { chunk_size: 500, prefetch_depth: 4, threads: 1, total },
        );
        assert!(
            pre.wall_seconds < sync.wall_seconds * 0.98,
            "prefetch {:.3}s !< sync {:.3}s",
            pre.wall_seconds,
            sync.wall_seconds
        );
    }

    #[test]
    fn hogwild_warmup_trains() {
        let cfg = ModelConfig::deep_ffm(4, 2, 256, &[8]);
        let src = SyntheticStream::with_buckets(DatasetSpec::tiny(), 6, 256);
        let (mut trainer, rep) = warmup_into_trainer(
            Regressor::new(&cfg),
            src,
            WarmupConfig { chunk_size: 2048, prefetch_depth: 2, threads: 3, total: 20_000 },
        );
        assert_eq!(rep.examples, 20_000);
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 7, 256);
        let test = s.take_examples(3000);
        let auc = trainer.test_auc(&test);
        assert!(auc > 0.55, "warmed auc {auc}");
    }
}
