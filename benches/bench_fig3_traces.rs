//! Figure 3 — rolling-window AUC traces of all engines across all
//! benchmark datasets (single pass).
//!
//! Emits one CSV per dataset into `bench_out/fig3_<dataset>.csv` with
//! columns: window_idx, engine, config, auc, in_ood_window.  The
//! expected shape: VW adapts faster with little data, FW-DeepFFM
//! dominates once enough data is seen; OOD windows depress everyone,
//! the FW engines less (stability).

use fwumious::baselines::dcnv2::DcnV2;
use fwumious::baselines::vw_linear::VwLinear;
use fwumious::baselines::vw_mlp::VwMlp;
use fwumious::baselines::{FwModel, OnlineModel};
use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::eval::RollingAuc;
use fwumious::model::regressor::Regressor;
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj, s};

const N: usize = 80_000;
const WINDOW: usize = 4_000;

fn trace(model: &mut dyn OnlineModel, spec: &DatasetSpec, buckets: u32) -> (Vec<f64>, Vec<bool>) {
    let mut s = SyntheticStream::with_buckets(spec.clone(), 3, buckets);
    let mut roll = RollingAuc::new(WINDOW);
    let mut ood_flags = Vec::new();
    let mut window_had_ood = false;
    for _ in 0..N {
        let ood = s.in_ood_window();
        window_had_ood |= ood;
        let ex = s.next_example();
        let p = model.learn(&ex);
        let before = roll.points.len();
        roll.add(p, ex.label);
        if roll.points.len() > before {
            ood_flags.push(window_had_ood);
            window_had_ood = false;
        }
    }
    (roll.points, ood_flags)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    std::fs::create_dir_all("bench_out").expect("mkdir bench_out");
    let buckets = 1u32 << 16;
    let mut rows = Vec::new();
    for spec in [
        DatasetSpec::criteo_like(),
        DatasetSpec::avazu_like(),
        DatasetSpec::kdd_like(),
    ] {
        let fields = spec.fields();
        let path = format!("bench_out/fig3_{}.csv", spec.name.replace('-', "_"));
        let mut csv = String::from("window,engine,config,auc,ood\n");
        println!("--- {} ({} examples, window {}) ---", spec.name, N, WINDOW);
        for (engine, lrs) in [
            ("VW-linear", vec![0.1f32, 0.3]),
            ("VW-mlp", vec![0.1, 0.3]),
            ("FW-FFM", vec![0.1, 0.3]),
            ("FW-DeepFFM", vec![0.1, 0.3]),
            ("DCNv2", vec![0.05, 0.15]),
        ] {
            for (ci, &lr) in lrs.iter().enumerate() {
                let mut model: Box<dyn OnlineModel> = match engine {
                    "VW-linear" => Box::new(VwLinear::new(buckets, lr, 0.5)),
                    "VW-mlp" => Box::new(VwMlp::new(buckets, 8, lr, 0.5, ci as u64)),
                    "FW-FFM" => {
                        let mut cfg = ModelConfig::ffm(fields, 4, buckets);
                        cfg.lr = lr;
                        cfg.ffm_lr = lr * 0.5;
                        Box::new(FwModel::new(engine, Regressor::new(&cfg)))
                    }
                    "FW-DeepFFM" => {
                        let mut cfg = ModelConfig::deep_ffm(fields, 4, buckets, &[16]);
                        cfg.lr = lr;
                        cfg.ffm_lr = lr * 0.5;
                        cfg.nn_lr = lr * 0.25;
                        Box::new(FwModel::new(engine, Regressor::new(&cfg)))
                    }
                    _ => Box::new(DcnV2::new(buckets, fields, 4, 2, lr, ci as u64)),
                };
                let (points, ood) = trace(model.as_mut(), &spec, buckets);
                let avg: f64 = points.iter().sum::<f64>() / points.len().max(1) as f64;
                let last = points.last().cloned().unwrap_or(0.5);
                println!(
                    "  {engine:<12} lr={lr:<5} avg={avg:.4} final={last:.4} ({} windows, {} OOD)",
                    points.len(),
                    ood.iter().filter(|&&o| o).count()
                );
                for (w, (p, o)) in points.iter().zip(&ood).enumerate() {
                    csv.push_str(&format!("{w},{engine},{ci},{p:.5},{}\n", *o as u8));
                }
                rows.push(obj(vec![
                    ("dataset", s(&spec.name)),
                    ("engine", s(engine)),
                    ("lr", num(lr as f64)),
                    ("avg_auc", num(avg)),
                    ("final_auc", num(last)),
                    ("windows", num(points.len() as f64)),
                    (
                        "ood_windows",
                        num(ood.iter().filter(|&&o| o).count() as f64),
                    ),
                ]));
            }
        }
        std::fs::write(&path, csv).expect("write csv");
        println!("  wrote {path}\n");
    }
    let path = bench_env::write_report(
        "fig3_traces",
        smoke,
        vec![
            ("examples", num(N as f64)),
            ("window", num(WINDOW as f64)),
            ("traces", arr(rows)),
        ],
    );
    println!("report -> {path}");
    println!("expected: FW-DeepFFM final AUC >= others; OOD windows dent all traces.");
}
