//! §6 — 16-bit dynamic-range weight quantization.
//!
//! Designed around the paper's three use-case constraints:
//!
//! 1. *Consistently small weight patches* — quantizing to a coarse,
//!    **stable** grid means small weight drift between training rounds
//!    maps to identical or near-identical u16 codes, so the byte diff
//!    of consecutive quantized files is tiny.
//! 2. *Fast* — quantization/dequantization are single passes ("the
//!    procedure has tens of seconds at most at its disposal for the
//!    full weight space"; here: hundreds of MB/s).
//! 3. *Dynamic ranges* — each update re-scans min/max because "weight
//!    update sizes [vary] based on e.g. time of the day".
//!
//! Bounds are **rounded to α (max) and β (min) decimals** before the
//! bucket size is computed — full-precision bounds made patch sizes
//! fluctuate ("quantization output tended to fluctuate more"), while
//! rounded bounds keep the grid stable across rounds.
//!
//! File format (little-endian):
//! ```text
//! magic  [4] b"FWQ1"
//! n      u64   weight count
//! min    f32   rounded minimum
//! bucket f32   bucket size
//! alpha  u8, beta u8, _pad u16
//! codes  [n * 2] u16
//! ```
//! "the original weights file is enriched with a header that contains
//! the bucket size and weight minimum — these two properties are
//! sufficient for efficient weight reconstruction."

use crate::util::math::round_decimals;

pub const MAGIC: &[u8; 4] = b"FWQ1";
/// Number of representable buckets ("the amount of possible values for
/// 16b representation is small (around 65k)").
pub const B_MAX: u32 = 65_535;

/// Quantization parameters (the file header).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantHeader {
    pub n: u64,
    pub min: f32,
    pub bucket: f32,
    pub alpha: u8,
    pub beta: u8,
}

/// Quantize `weights` to u16 codes.  `alpha`/`beta` are the decimal
/// precisions for the max/min bounds.
pub fn quantize(weights: &[f32], alpha: u8, beta: u8) -> (QuantHeader, Vec<u16>) {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &w in weights {
        lo = lo.min(w);
        hi = hi.max(w);
    }
    if weights.is_empty() {
        lo = 0.0;
        hi = 0.0;
    }
    // Round bounds outward at the requested precisions so every weight
    // stays inside [min_r, max_r].
    let step_b = 10f32.powi(-(beta as i32));
    let step_a = 10f32.powi(-(alpha as i32));
    let mut min_r = round_decimals(lo, beta as u32);
    if min_r > lo {
        min_r -= step_b;
    }
    let mut max_r = round_decimals(hi, alpha as u32);
    if max_r < hi {
        max_r += step_a;
    }
    let bucket = if max_r > min_r {
        (max_r - min_r) / B_MAX as f32
    } else {
        1.0 // degenerate range: all codes 0
    };
    let inv = 1.0 / bucket;
    let codes = weights
        .iter()
        .map(|&w| {
            let q = ((w - min_r) * inv).round();
            q.clamp(0.0, B_MAX as f32) as u16
        })
        .collect();
    (
        QuantHeader { n: weights.len() as u64, min: min_r, bucket, alpha, beta },
        codes,
    )
}

/// True when this header's representable range covers `[lo, hi]`.
impl QuantHeader {
    pub fn covers(&self, lo: f32, hi: f32) -> bool {
        lo >= self.min && hi <= self.min + self.bucket * B_MAX as f32
    }
}

/// Quantize against an existing grid (grid reuse keeps consecutive
/// rounds' codes aligned, which is what makes quantized patches tiny —
/// the "dynamically select viable weight ranges" requirement of §6).
/// Returns `None` when the weights escape the grid's range.
pub fn quantize_with(header: &QuantHeader, weights: &[f32]) -> Option<Vec<u16>> {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &w in weights {
        lo = lo.min(w);
        hi = hi.max(w);
    }
    if weights.is_empty() {
        lo = 0.0;
        hi = 0.0;
    }
    if !header.covers(lo, hi) || weights.len() as u64 != header.n {
        return None;
    }
    let inv = 1.0 / header.bucket;
    Some(
        weights
            .iter()
            .map(|&w| ((w - header.min) * inv).round().clamp(0.0, B_MAX as f32) as u16)
            .collect(),
    )
}

/// Like [`quantize`], but widens the rounded bounds by `headroom`
/// (fraction of the span) so a slowly drifting weight distribution
/// stays inside the grid across many rounds.
pub fn quantize_headroom(
    weights: &[f32],
    alpha: u8,
    beta: u8,
    headroom: f32,
) -> (QuantHeader, Vec<u16>) {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &w in weights {
        lo = lo.min(w);
        hi = hi.max(w);
    }
    if weights.is_empty() {
        lo = 0.0;
        hi = 0.0;
    }
    let span = (hi - lo).max(1e-6);
    let padded: Vec<f32> = vec![lo - span * headroom, hi + span * headroom];
    // reuse quantize()'s rounding on the padded bounds
    let (mut h, _) = quantize(&padded, alpha, beta);
    h.n = weights.len() as u64;
    // The padded bounds bracket [lo, hi] by construction and quantize()
    // only rounds them outward, so the grid always covers the weights;
    // fall back to a fresh grid rather than panicking if that invariant
    // ever slips (e.g. under pathological float rounding).
    match quantize_with(&h, weights) {
        Some(codes) => (h, codes),
        None => quantize(weights, alpha, beta),
    }
}

/// Reconstruct weights from codes: `w = min + code * bucket`.
pub fn dequantize(header: &QuantHeader, codes: &[u16]) -> Vec<f32> {
    debug_assert_eq!(codes.len() as u64, header.n);
    codes
        .iter()
        .map(|&c| header.min + c as f32 * header.bucket)
        .collect()
}

/// Serialize header + codes into the FWQ1 byte format.
pub fn to_bytes(header: &QuantHeader, codes: &[u16]) -> Vec<u8> {
    let mut out = Vec::with_capacity(24 + codes.len() * 2);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&header.n.to_le_bytes());
    out.extend_from_slice(&header.min.to_le_bytes());
    out.extend_from_slice(&header.bucket.to_le_bytes());
    out.push(header.alpha);
    out.push(header.beta);
    out.extend_from_slice(&[0u8; 2]);
    for &c in codes {
        out.extend_from_slice(&c.to_le_bytes());
    }
    out
}

/// Why [`from_bytes`] rejected a FWQ1 buffer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QuantError {
    /// Too short or wrong magic.
    BadHeader,
    /// Code payload does not match the declared weight count.
    PayloadMismatch { payload: usize, n: u64 },
}

impl std::fmt::Display for QuantError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QuantError::BadHeader => write!(f, "bad FWQ1 header"),
            QuantError::PayloadMismatch { payload, n } => {
                write!(f, "payload {payload} bytes != 2 * n ({n})")
            }
        }
    }
}

impl std::error::Error for QuantError {}

/// CLI shim: `fn main` paths print errors as strings.
impl From<QuantError> for String {
    fn from(e: QuantError) -> String {
        e.to_string()
    }
}

/// Parse the FWQ1 byte format.
pub fn from_bytes(buf: &[u8]) -> Result<(QuantHeader, Vec<u16>), QuantError> {
    if buf.len() < 24 || &buf[..4] != MAGIC {
        return Err(QuantError::BadHeader);
    }
    let n = u64::from_le_bytes([
        buf[4], buf[5], buf[6], buf[7], buf[8], buf[9], buf[10], buf[11],
    ]);
    let min = f32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]);
    let bucket = f32::from_le_bytes([buf[16], buf[17], buf[18], buf[19]]);
    let alpha = buf[20];
    let beta = buf[21];
    let payload = &buf[24..];
    if payload.len() != n as usize * 2 {
        return Err(QuantError::PayloadMismatch { payload: payload.len(), n });
    }
    let codes = payload
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect();
    Ok((QuantHeader { n, min, bucket, alpha, beta }, codes))
}

/// One-shot: quantize weights straight to bytes (the online pipeline).
pub fn quantize_to_bytes(weights: &[f32], alpha: u8, beta: u8) -> Vec<u8> {
    let (h, codes) = quantize(weights, alpha, beta);
    to_bytes(&h, &codes)
}

/// One-shot inverse.
pub fn dequantize_from_bytes(buf: &[u8]) -> Result<Vec<f32>, QuantError> {
    let (h, codes) = from_bytes(buf)?;
    Ok(dequantize(&h, &codes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;
    use crate::util::rng::Pcg32;

    fn randw(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    #[test]
    fn roundtrip_error_bounded_by_half_bucket() {
        let w = randw(10_000, 1, 0.5);
        let (h, codes) = quantize(&w, 2, 2);
        let back = dequantize(&h, &codes);
        for (a, b) in w.iter().zip(&back) {
            assert!(
                (a - b).abs() <= h.bucket * 0.5 + 1e-6,
                "{a} vs {b} bucket {}",
                h.bucket
            );
        }
    }

    #[test]
    fn bounds_cover_all_weights() {
        let w = randw(1000, 2, 3.0);
        let (h, codes) = quantize(&w, 1, 1);
        let lo = w.iter().cloned().fold(f32::MAX, f32::min);
        let hi = w.iter().cloned().fold(f32::MIN, f32::max);
        assert!(h.min <= lo);
        assert!(h.min + B_MAX as f32 * h.bucket >= hi - 1e-4);
        // codes span a good part of the range
        assert!(*codes.iter().max().unwrap() > 30_000);
    }

    #[test]
    fn bytes_half_of_f32() {
        let w = randw(5000, 3, 1.0);
        let bytes = quantize_to_bytes(&w, 2, 2);
        assert_eq!(bytes.len(), 24 + 2 * 5000);
        assert!(bytes.len() * 2 < w.len() * 4 + 100);
    }

    #[test]
    fn byte_format_roundtrip() {
        let w = randw(777, 4, 0.2);
        let bytes = quantize_to_bytes(&w, 3, 2);
        let back = dequantize_from_bytes(&bytes).unwrap();
        let direct = {
            let (h, c) = quantize(&w, 3, 2);
            dequantize(&h, &c)
        };
        assert_eq!(back, direct);
    }

    #[test]
    fn rounded_bounds_are_stable_across_small_drift() {
        // the α/β rounding means a slightly drifted weight set maps to
        // the SAME grid -> most codes identical (small patches).
        let w1 = randw(20_000, 5, 0.5);
        let mut w2 = w1.clone();
        let mut rng = Pcg32::seeded(6);
        for w in w2.iter_mut().take(200) {
            *w += rng.normal() * 1e-4;
        }
        let (h1, c1) = quantize(&w1, 2, 2);
        let (h2, c2) = quantize(&w2, 2, 2);
        assert_eq!(h1.min, h2.min, "grid must not move under tiny drift");
        assert_eq!(h1.bucket, h2.bucket);
        let changed = c1.iter().zip(&c2).filter(|(a, b)| a != b).count();
        assert!(changed <= 400, "changed codes {changed}");
    }

    #[test]
    fn degenerate_inputs() {
        // constant weights
        let w = vec![0.25f32; 100];
        let (h, codes) = quantize(&w, 2, 2);
        let back = dequantize(&h, &codes);
        for b in back {
            assert!((b - 0.25).abs() <= h.bucket * 0.5 + 1e-6);
        }
        // empty
        let (h, codes) = quantize(&[], 2, 2);
        assert_eq!(h.n, 0);
        assert!(codes.is_empty());
        assert_eq!(dequantize(&h, &codes), Vec::<f32>::new());
    }

    #[test]
    fn corrupt_bytes_rejected() {
        assert!(from_bytes(b"nope").is_err());
        let w = randw(10, 7, 1.0);
        let mut bytes = quantize_to_bytes(&w, 2, 2);
        bytes.pop();
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn prop_roundtrip_error_bound() {
        prop(40, |g| {
            let scale = g.f32_in(0.01, 10.0);
            let w = g.vec_normal(1..2000, scale);
            let alpha = g.usize_in(1..5) as u8;
            let beta = g.usize_in(1..5) as u8;
            let (h, codes) = quantize(&w, alpha, beta);
            let back = dequantize(&h, &codes);
            for (a, b) in w.iter().zip(&back) {
                assert!((a - b).abs() <= h.bucket * 0.5 + 1e-5);
            }
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 10M floats + wall-clock assert: Miri is ~1000x slower
    fn quantization_throughput_fast_enough() {
        // §6: "procedure has tens of seconds at most"; we check the
        // in-process path handles ~40 MB of weights in well under 2 s.
        let w = randw(10_000_000, 8, 0.3);
        let t = std::time::Instant::now();
        let bytes = quantize_to_bytes(&w, 2, 2);
        let secs = t.elapsed().as_secs_f64();
        assert!(bytes.len() > 0);
        assert!(secs < 2.0, "quantize took {secs}s");
    }
}
