//! The online deployment plane — §3 + §6 run as one live system.
//!
//! The paper's production regime is not any single component but the
//! *loop*: Hogwild online training produces a weight snapshot every few
//! minutes, the snapshot is quantized and byte-patched for cross-DC
//! transfer, and serving workers hot-swap it without dropping traffic
//! (the always-online FFM deployments of Juan et al., arXiv:1701.04099).
//! [`DeploymentLoop`] owns that round lifecycle end to end:
//!
//! ```text
//!   train ──► encode ──► channel ──► decode ──► swap
//!   (Hogwild  (UpdatePipeline:       (UpdateReceiver   (ModelHandle::swap
//!    rounds)   raw/quant/patch/       reconstructs      + cache epoch
//!              quant+patch)           the weights)      invalidation)
//! ```
//!
//! Serving continues concurrently throughout — traffic drivers score
//! through [`crate::serve::server::ServeClient`] clones while rounds
//! run — and the loop exposes per-round lag/bandwidth/AUC metrics (the
//! numbers behind Table 4 and Figure 6, measured live instead of in
//! isolation).  [`harness`] builds the deterministic soak rig on top.

pub mod harness;

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::config::{ModelConfig, ServeConfig};
use crate::data::synthetic::{DatasetSpec, SyntheticStream};
use crate::eval::auc;
use crate::feature::Example;
use crate::fleet::checkpoint::{
    mode_from_tag, mode_tag, ByteReader, ByteWriter,
};
use crate::model::regressor::Regressor;
use crate::model::{io, Workspace};
use crate::obs::{Counter, Gauge, HistogramShard, ObsOptions, RequestTracer};
use crate::serve::router::Router;
use crate::serve::server::{ServeClient, ServeStats, ServingEngine};
use crate::serve::ModelHandle;
use crate::train::hogwild::{train_chunk, HogwildConfig};
use crate::transfer::{
    FleetError, SimulatedChannel, UpdateMode, UpdatePipeline, UpdateReceiver,
};
use crate::util::json::{num, obj, s};

/// Why a deployment-loop operation failed: a checkpoint that does not
/// match the configuration, a corrupt trainer snapshot, or a failure
/// in the underlying fleet plane (wire decode, checkpoint IO).
#[derive(Clone, Debug, PartialEq)]
pub enum DeployError {
    /// Checkpoint encodes a different wire mode than the config.
    ModeMismatch { checkpoint: UpdateMode, configured: UpdateMode },
    /// The checkpointed trainer snapshot failed to decode.
    TrainerSnapshot(String),
    /// A non-bootstrap checkpoint is missing its receiver base.
    MissingReceiverBase { round: u64 },
    /// The underlying fleet plane failed.
    Fleet(FleetError),
}

impl std::fmt::Display for DeployError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeployError::ModeMismatch { checkpoint, configured } => {
                write!(f, "checkpoint mode {checkpoint:?} != configured {configured:?}")
            }
            DeployError::TrainerSnapshot(e) => write!(f, "trainer snapshot: {e}"),
            DeployError::MissingReceiverBase { round } => {
                write!(f, "checkpoint claims round {round} with no receiver base")
            }
            DeployError::Fleet(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for DeployError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DeployError::Fleet(e) => Some(e),
            _ => None,
        }
    }
}

impl From<FleetError> for DeployError {
    fn from(e: FleetError) -> DeployError {
        DeployError::Fleet(e)
    }
}

/// CLI shim: `fn main` paths print errors as strings.
impl From<DeployError> for String {
    fn from(e: DeployError) -> String {
        e.to_string()
    }
}

/// Configuration of one deployment plane instance.
#[derive(Clone, Debug)]
pub struct DeployConfig {
    /// Model architecture served and trained.
    pub model: ModelConfig,
    /// Synthetic traffic shape feeding the trainer.
    pub dataset: DatasetSpec,
    /// Wire encoding (the four Table-4 arms).
    pub mode: UpdateMode,
    /// Examples consumed per training round (the "5-minute window").
    pub examples_per_round: usize,
    /// Hogwild threads for each round (1 = sequential, deterministic).
    pub train_threads: usize,
    /// Rolling-AUC window for the per-round training trace.
    pub auc_window: usize,
    /// Serving engine configuration.
    pub serve: ServeConfig,
    /// Name the model is registered under in the router.
    pub model_name: String,
    /// Held-out examples scored after every swap (AUC trend); 0
    /// disables the evaluation.
    pub holdout_examples: usize,
    /// Simulated inter-DC link.
    pub bandwidth_bps: f64,
    pub rtt_seconds: f64,
    /// Base seed for the training / holdout streams.
    pub seed: u64,
    /// Write a durable checkpoint every N rounds (0 = off).  Requires
    /// [`checkpoint_path`](Self::checkpoint_path).
    pub checkpoint_every_rounds: usize,
    /// Where the checkpoint lives (CRC-sealed, atomic rename-on-write).
    pub checkpoint_path: Option<PathBuf>,
}

impl DeployConfig {
    /// Sensible defaults around a given model/dataset/mode.
    pub fn new(model: ModelConfig, dataset: DatasetSpec, mode: UpdateMode) -> Self {
        DeployConfig {
            model,
            dataset,
            mode,
            examples_per_round: 10_000,
            train_threads: 1,
            auc_window: 2_000,
            serve: ServeConfig::default(),
            model_name: "ctr".into(),
            holdout_examples: 2_000,
            bandwidth_bps: 125_000_000.0, // 1 Gbps
            rtt_seconds: 0.03,
            seed: 0xf10c,
            checkpoint_every_rounds: 0,
            checkpoint_path: None,
        }
    }
}

/// Everything measured about one train→publish→swap round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    /// 0-based round index.
    pub round: usize,
    /// Examples trained this round.
    pub examples: usize,
    /// Wall time of the Hogwild training phase.
    pub train_seconds: f64,
    /// Mean rolling-AUC of this round's progressive validation.
    pub train_auc: f64,
    /// Encoder wall time (Table 4 "Avg. time spent").
    pub encode_seconds: f64,
    /// Simulated wire time on the inter-DC channel.
    pub wire_seconds: f64,
    /// Receiver decode + reconstruction wall time.
    pub apply_seconds: f64,
    /// Bytes shipped for this update.
    pub update_bytes: usize,
    /// Size of the raw inference file (the baseline this update is
    /// measured against).
    pub raw_bytes: usize,
    /// Model version after the swap.
    pub version: u64,
    /// Publish lag: snapshot ready → serving on the new weights
    /// (encode + wire + apply + swap).
    pub lag_seconds: f64,
    /// Held-out AUC of the *served* (post-swap) model; NaN when the
    /// holdout evaluation is disabled.
    pub holdout_auc: f64,
}

/// Accumulated loop metrics (the live Table-4/Figure-6 ledger).
#[derive(Clone, Debug, Default)]
pub struct DeployMetrics {
    pub rounds: u64,
    pub examples: u64,
    pub update_bytes_total: u64,
    pub raw_bytes_total: u64,
    pub encode_seconds_total: f64,
    pub wire_seconds_total: f64,
    pub apply_seconds_total: f64,
    pub lag_seconds_total: f64,
    pub last_version: u64,
    pub last_holdout_auc: f64,
}

impl DeployMetrics {
    fn absorb(&mut self, r: &RoundReport) {
        self.rounds += 1;
        self.examples += r.examples as u64;
        self.update_bytes_total += r.update_bytes as u64;
        self.raw_bytes_total += r.raw_bytes as u64;
        self.encode_seconds_total += r.encode_seconds;
        self.wire_seconds_total += r.wire_seconds;
        self.apply_seconds_total += r.apply_seconds;
        self.lag_seconds_total += r.lag_seconds;
        self.last_version = r.version;
        self.last_holdout_auc = r.holdout_auc;
    }

    /// Raw-bytes / shipped-bytes ratio (×1 for `UpdateMode::Raw`).
    pub fn bandwidth_saving(&self) -> f64 {
        if self.update_bytes_total == 0 {
            0.0
        } else {
            self.raw_bytes_total as f64 / self.update_bytes_total as f64
        }
    }

    pub fn mean_lag_seconds(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.lag_seconds_total / self.rounds as f64
        }
    }
}

/// Durable snapshot of one [`DeploymentLoop`]: everything needed to
/// resume the train→publish→swap cycle after a crash.  Shares the
/// `FWCKPT1` framing (CRC seal, atomic write) with
/// [`crate::fleet::checkpoint`]; the payloads are distinguished by
/// their leading version byte (fabric = 1, deploy = 2).
///
/// With `train_threads == 1` a restored loop resumes
/// **bit-identically**: the trainer snapshot includes optimizer state,
/// the synthetic stream is fast-forwarded to the exact crash position,
/// and the pipeline/receiver diff bases are restored byte-for-byte, so
/// resumed rounds encode the same updates an uninterrupted run would.
/// (Hogwild rounds with >1 thread are racy by design; recovery is
/// still exact up to the checkpoint, resumed rounds then race anew.)
#[derive(Clone, Debug)]
pub struct DeployCheckpoint {
    pub mode: UpdateMode,
    /// Rounds completed at checkpoint time.
    pub round: u64,
    /// Training-stream position (examples drawn since round 0).
    pub examples_consumed: u64,
    /// Served model version at checkpoint time.
    pub version: u64,
    /// Trainer snapshot *with* optimizer state
    /// ([`io::to_bytes`]`(_, true)`).
    pub trainer: Vec<u8>,
    /// Sender pipeline diff bases.
    pub prev_raw: Option<Vec<u8>>,
    pub prev_quant: Option<Vec<u8>>,
    /// Receiver base file (the served model's wire form); None before
    /// the first round.
    pub receiver_base: Option<Vec<u8>>,
    pub metrics: DeployMetrics,
}

impl DeployCheckpoint {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u8(2); // deploy payload version
        w.put_u8(mode_tag(self.mode));
        w.put_u64(self.round);
        w.put_u64(self.examples_consumed);
        w.put_u64(self.version);
        w.put_bytes(&self.trainer);
        w.put_opt_bytes(self.prev_raw.as_deref());
        w.put_opt_bytes(self.prev_quant.as_deref());
        w.put_opt_bytes(self.receiver_base.as_deref());
        let m = &self.metrics;
        w.put_u64(m.rounds);
        w.put_u64(m.examples);
        w.put_u64(m.update_bytes_total);
        w.put_u64(m.raw_bytes_total);
        w.put_f64(m.encode_seconds_total);
        w.put_f64(m.wire_seconds_total);
        w.put_f64(m.apply_seconds_total);
        w.put_f64(m.lag_seconds_total);
        w.put_u64(m.last_version);
        w.put_f64(m.last_holdout_auc);
        w.finish()
    }

    pub fn from_bytes(payload: &[u8]) -> Result<DeployCheckpoint, FleetError> {
        let mut r = ByteReader::new(payload);
        let version_tag = r.get_u8()?;
        if version_tag != 2 {
            return Err(FleetError::Corrupt(format!(
                "unsupported deploy checkpoint version {version_tag}"
            )));
        }
        let mode = mode_from_tag(r.get_u8()?)?;
        let round = r.get_u64()?;
        let examples_consumed = r.get_u64()?;
        let version = r.get_u64()?;
        let trainer = r.get_bytes()?;
        let prev_raw = r.get_opt_bytes()?;
        let prev_quant = r.get_opt_bytes()?;
        let receiver_base = r.get_opt_bytes()?;
        let metrics = DeployMetrics {
            rounds: r.get_u64()?,
            examples: r.get_u64()?,
            update_bytes_total: r.get_u64()?,
            raw_bytes_total: r.get_u64()?,
            encode_seconds_total: r.get_f64()?,
            wire_seconds_total: r.get_f64()?,
            apply_seconds_total: r.get_f64()?,
            lag_seconds_total: r.get_f64()?,
            last_version: r.get_u64()?,
            last_holdout_auc: r.get_f64()?,
        };
        r.done()?;
        Ok(DeployCheckpoint {
            mode,
            round,
            examples_consumed,
            version,
            trainer,
            prev_raw,
            prev_quant,
            receiver_base,
            metrics,
        })
    }
}

/// Registry handles for the deploy plane's own signals (rounds, lag,
/// swap latency, update bytes, holdout AUC).
struct DeployObs {
    rounds: Gauge,
    round_lag: Gauge,
    holdout_auc: Gauge,
    update_bytes: Counter,
    swap_ns: HistogramShard,
    tracer: Option<RequestTracer>,
}

/// The deployment plane: training DC, transfer plane and serving DC
/// wired into one continuously publishing loop.
pub struct DeploymentLoop {
    pub cfg: DeployConfig,
    trainer: Regressor,
    stream: SyntheticStream,
    pipeline: UpdatePipeline,
    receiver: UpdateReceiver,
    channel: SimulatedChannel,
    handle: ModelHandle,
    engine: ServingEngine,
    holdout: Vec<Example>,
    metrics: DeployMetrics,
    round: usize,
    /// Training-stream position, checkpointed so a restored loop can
    /// fast-forward its stream to the exact crash point.
    examples_consumed: u64,
    obs: DeployObs,
}

impl std::fmt::Debug for DeploymentLoop {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeploymentLoop").finish_non_exhaustive()
    }
}

impl DeploymentLoop {
    /// Build the full plane: fresh model, registered serving engine,
    /// transfer pipeline/receiver pair and a held-out evaluation set.
    pub fn new(cfg: DeployConfig) -> Self {
        Self::with_obs(cfg, ObsOptions::default())
    }

    /// [`new`](Self::new) recording into a caller-provided registry
    /// (and optionally tracing swap events), so serving, deploy, and
    /// training signals land in ONE scrape.
    pub fn with_obs(cfg: DeployConfig, obs: ObsOptions) -> Self {
        let trainer = Regressor::new(&cfg.model);
        let stream = SyntheticStream::with_buckets(
            cfg.dataset.clone(),
            cfg.seed,
            cfg.model.buckets,
        );
        let mut holdout_stream = SyntheticStream::with_buckets(
            cfg.dataset.clone(),
            cfg.seed ^ 0x0e1d_0a7a,
            cfg.model.buckets,
        );
        let holdout = holdout_stream.take_examples(cfg.holdout_examples);

        let pipeline = UpdatePipeline::new(cfg.mode);
        let mut receiver = UpdateReceiver::new(cfg.mode);
        receiver.set_template(trainer.clone());
        let channel =
            SimulatedChannel::with_bandwidth(cfg.bandwidth_bps, cfg.rtt_seconds);

        let handle = ModelHandle::new(trainer.clone());
        let router = Router::new(cfg.serve.workers);
        router.register(&cfg.model_name, handle.clone());
        let engine =
            ServingEngine::start_with_obs(router, cfg.serve.clone(), obs.clone());
        let reg = engine.obs_registry().clone();
        let deploy_obs = DeployObs {
            rounds: reg.gauge("fw_deploy_rounds", "publish rounds completed"),
            round_lag: reg.gauge(
                "fw_deploy_round_lag_seconds",
                "last round's publish lag (encode + wire + apply + swap)",
            ),
            holdout_auc: reg.gauge(
                "fw_deploy_holdout_auc",
                "held-out AUC of the served model after the last swap",
            ),
            update_bytes: reg.counter(
                "fw_deploy_update_bytes_total",
                "bytes shipped across rounds",
            ),
            swap_ns: reg.histogram_shard(
                "fw_deploy_swap_ns",
                "hot-swap latency (snapshot publish to cache invalidation)",
            ),
            tracer: obs.tracer,
        };

        DeploymentLoop {
            cfg,
            trainer,
            stream,
            pipeline,
            receiver,
            channel,
            handle,
            engine,
            holdout,
            metrics: DeployMetrics::default(),
            round: 0,
            examples_consumed: 0,
            obs: deploy_obs,
        }
    }

    /// Rebuild a loop from a durable checkpoint (see
    /// [`DeployCheckpoint`] for the resume guarantees).  The recovery
    /// wall time — restore to ready-to-serve — lands in the registry's
    /// `fw_recovery_replay_ns` histogram.
    pub fn restore_with_obs(
        cfg: DeployConfig,
        obs: ObsOptions,
        ckpt: &DeployCheckpoint,
    ) -> Result<Self, DeployError> {
        if ckpt.mode != cfg.mode {
            return Err(DeployError::ModeMismatch {
                checkpoint: ckpt.mode,
                configured: cfg.mode,
            });
        }
        let t0 = Instant::now();
        let trainer = io::from_bytes(&ckpt.trainer)
            .map_err(|e| DeployError::TrainerSnapshot(e.to_string()))?;
        // fast-forward the training stream to the crash point so
        // resumed rounds draw the same examples an uninterrupted run
        // would have
        let mut stream = SyntheticStream::with_buckets(
            cfg.dataset.clone(),
            cfg.seed,
            cfg.model.buckets,
        );
        let _ = stream.take_examples(ckpt.examples_consumed as usize);
        let mut holdout_stream = SyntheticStream::with_buckets(
            cfg.dataset.clone(),
            cfg.seed ^ 0x0e1d_0a7a,
            cfg.model.buckets,
        );
        let holdout = holdout_stream.take_examples(cfg.holdout_examples);

        let mut pipeline = UpdatePipeline::new(cfg.mode);
        pipeline.restore_state(ckpt.prev_raw.clone(), ckpt.prev_quant.clone())?;
        let mut receiver = UpdateReceiver::new(cfg.mode);
        receiver.set_template(Regressor::new(&cfg.model));
        let served = match &ckpt.receiver_base {
            Some(base) => receiver.resync(base)?,
            None => {
                if ckpt.round != 0 {
                    return Err(DeployError::MissingReceiverBase { round: ckpt.round });
                }
                Regressor::new(&cfg.model)
            }
        };
        let channel =
            SimulatedChannel::with_bandwidth(cfg.bandwidth_bps, cfg.rtt_seconds);

        // the handle resumes at the checkpointed version so the served
        // version line stays monotonic across the crash
        let handle = ModelHandle::at_version(served, ckpt.version);
        let router = Router::new(cfg.serve.workers);
        router.register(&cfg.model_name, handle.clone());
        let engine =
            ServingEngine::start_with_obs(router, cfg.serve.clone(), obs.clone());
        let reg = engine.obs_registry().clone();
        let deploy_obs = DeployObs {
            rounds: reg.gauge("fw_deploy_rounds", "publish rounds completed"),
            round_lag: reg.gauge(
                "fw_deploy_round_lag_seconds",
                "last round's publish lag (encode + wire + apply + swap)",
            ),
            holdout_auc: reg.gauge(
                "fw_deploy_holdout_auc",
                "held-out AUC of the served model after the last swap",
            ),
            update_bytes: reg.counter(
                "fw_deploy_update_bytes_total",
                "bytes shipped across rounds",
            ),
            swap_ns: reg.histogram_shard(
                "fw_deploy_swap_ns",
                "hot-swap latency (snapshot publish to cache invalidation)",
            ),
            tracer: obs.tracer,
        };
        deploy_obs.rounds.set(ckpt.round as f64);
        reg.histogram_shard(
            "fw_recovery_replay_ns",
            "crash-recovery replay/catch-up wall time (ns)",
        )
        .record_ns(t0.elapsed().as_nanos() as u64);
        if let Some(tr) = deploy_obs.tracer.as_ref() {
            tr.emit(&obj(vec![
                ("event", s("deploy_restore")),
                ("round", num(ckpt.round as f64)),
                ("version", num(ckpt.version as f64)),
            ]));
        }

        Ok(DeploymentLoop {
            cfg,
            trainer,
            stream,
            pipeline,
            receiver,
            channel,
            handle,
            engine,
            holdout,
            metrics: ckpt.metrics.clone(),
            round: ckpt.round as usize,
            examples_consumed: ckpt.examples_consumed,
            obs: deploy_obs,
        })
    }

    /// [`restore_with_obs`](Self::restore_with_obs) from a sealed
    /// checkpoint file.
    pub fn restore_from_path(
        cfg: DeployConfig,
        obs: ObsOptions,
        path: &Path,
    ) -> Result<Self, DeployError> {
        let payload = crate::fleet::checkpoint::read_file(path)?;
        let ckpt = DeployCheckpoint::from_bytes(&payload)?;
        Self::restore_with_obs(cfg, obs, &ckpt)
    }

    /// Snapshot the loop's durable state.
    pub fn checkpoint(&self) -> DeployCheckpoint {
        let (prev_raw, prev_quant) = self.pipeline.export_state();
        DeployCheckpoint {
            mode: self.cfg.mode,
            round: self.round as u64,
            examples_consumed: self.examples_consumed,
            version: self.handle.version(),
            trainer: io::to_bytes(&self.trainer, true),
            prev_raw,
            prev_quant,
            receiver_base: self.receiver.base_bytes().map(|b| b.to_vec()),
            metrics: self.metrics.clone(),
        }
    }

    /// Write the loop checkpoint to `path` (CRC-sealed, temp-file +
    /// rename).
    pub fn write_checkpoint(&self, path: &Path) -> Result<(), FleetError> {
        crate::fleet::checkpoint::write_atomic(path, &self.checkpoint().to_bytes())
    }

    /// One full round: train → encode → ship → decode → swap.
    pub fn run_round(&mut self) -> Result<RoundReport, DeployError> {
        self.run_round_with(|_, _| {})
    }

    /// [`run_round`](Self::run_round) with a hook that observes the
    /// reconstructed model *before* it is swapped in (the soak harness
    /// registers expected scores there, so concurrent traffic never
    /// sees a version it cannot verify).  The hook receives the fresh
    /// model and the version it will be published as.
    pub fn run_round_with(
        &mut self,
        before_swap: impl FnOnce(&Regressor, u64),
    ) -> Result<RoundReport, DeployError> {
        let round = self.round;
        // 1. online training window
        let chunk = self.stream.take_examples(self.cfg.examples_per_round);
        let stats = train_chunk(
            &mut self.trainer,
            &chunk,
            HogwildConfig { threads: self.cfg.train_threads.max(1) },
            self.cfg.auc_window,
        );
        let train_auc = if stats.auc_points.is_empty() {
            f64::NAN
        } else {
            stats.auc_points.iter().sum::<f64>() / stats.auc_points.len() as f64
        };
        // 2. encode for the wire
        let update = self.pipeline.encode(&self.trainer);
        let raw_bytes = self
            .pipeline
            .last_raw_len()
            .unwrap_or_else(|| io::to_bytes(&self.trainer, false).len());
        // 3. ship across the simulated inter-DC link
        let wire_seconds = self.channel.ship(&update);
        // 4. receive + reconstruct
        let t_apply = Instant::now();
        let fresh = self.receiver.apply(&update)?;
        let apply_seconds = t_apply.elapsed().as_secs_f64();
        // 5. publish: atomic snapshot swap + cache invalidation
        let next_version = self.handle.version() + 1;
        before_swap(&fresh, next_version);
        let t_swap = Instant::now();
        let version = self.handle.swap(fresh);
        self.engine.invalidate_caches();
        let swap_seconds = t_swap.elapsed().as_secs_f64();
        debug_assert_eq!(version, next_version);

        let holdout_auc = self.holdout_auc();
        let report = RoundReport {
            round,
            examples: chunk.len(),
            train_seconds: stats.wall_seconds,
            train_auc,
            encode_seconds: update.encode_seconds,
            wire_seconds,
            apply_seconds,
            update_bytes: update.bytes.len(),
            raw_bytes,
            version,
            lag_seconds: update.encode_seconds
                + wire_seconds
                + apply_seconds
                + swap_seconds,
            holdout_auc,
        };
        self.metrics.absorb(&report);
        self.round += 1;
        self.examples_consumed += report.examples as u64;

        // durable checkpoint cadence: every N completed rounds
        if self.cfg.checkpoint_every_rounds > 0
            && self.round % self.cfg.checkpoint_every_rounds == 0
        {
            if let Some(path) = self.cfg.checkpoint_path.clone() {
                self.write_checkpoint(&path)?;
            }
        }

        // Registry view of the round: training throughput/AUC, round
        // lag, swap latency, shipped bytes — same registry as serving.
        stats.export_to(self.engine.obs_registry());
        self.obs.rounds.set(self.round as f64);
        self.obs.round_lag.set(report.lag_seconds);
        if report.holdout_auc.is_finite() {
            self.obs.holdout_auc.set(report.holdout_auc);
        }
        self.obs.update_bytes.add(report.update_bytes as u64);
        self.obs
            .swap_ns
            .record_ns((swap_seconds * 1e9).min(u64::MAX as f64) as u64);
        if let Some(tr) = self.obs.tracer.as_ref() {
            tr.emit(&obj(vec![
                ("event", s("deploy_swap")),
                ("round", num(round as f64)),
                ("version", num(version as f64)),
                ("swap_ns", num(swap_seconds * 1e9)),
                ("lag_seconds", num(report.lag_seconds)),
                ("update_bytes", num(report.update_bytes as f64)),
            ]));
        }
        Ok(report)
    }

    /// Run `n` rounds back to back.
    pub fn run_rounds(&mut self, n: usize) -> Result<Vec<RoundReport>, DeployError> {
        (0..n).map(|_| self.run_round()).collect()
    }

    /// AUC of the currently *served* model on the fixed held-out set.
    pub fn holdout_auc(&self) -> f64 {
        if self.holdout.is_empty() {
            return f64::NAN;
        }
        let model = self.handle.load();
        let mut ws = Workspace::new();
        let mut scores = Vec::with_capacity(self.holdout.len());
        let mut labels = Vec::with_capacity(self.holdout.len());
        for ex in &self.holdout {
            scores.push(model.predict(ex, &mut ws));
            labels.push(ex.label);
        }
        auc(&scores, &labels)
    }

    // ------------------------------------------------------- accessors

    /// The serving engine (submit / stats on the caller's thread).
    pub fn engine(&self) -> &ServingEngine {
        &self.engine
    }

    /// A clonable traffic handle for driver threads (submits after
    /// [`shutdown`](Self::shutdown) fail with an error).
    pub fn client(&self) -> ServeClient {
        self.engine.client()
    }

    /// The hot-swappable model slot serving traffic.
    pub fn handle(&self) -> &ModelHandle {
        &self.handle
    }

    /// Trainer-side model state (the next snapshot's source).
    pub fn trainer(&self) -> &Regressor {
        &self.trainer
    }

    /// Sender-side pipeline (base-file introspection).
    pub fn pipeline(&self) -> &UpdatePipeline {
        &self.pipeline
    }

    /// Receiver-side state (base-file introspection).
    pub fn receiver(&self) -> &UpdateReceiver {
        &self.receiver
    }

    /// Bandwidth ledger of the simulated channel.
    pub fn channel(&self) -> &SimulatedChannel {
        &self.channel
    }

    /// Accumulated loop metrics.
    pub fn metrics(&self) -> &DeployMetrics {
        &self.metrics
    }

    /// Rounds completed so far.
    pub fn rounds_run(&self) -> usize {
        self.round
    }

    /// Stop serving; returns the engine's final statistics.
    pub fn shutdown(self) -> ServeStats {
        self.engine.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(mode: UpdateMode) -> DeployConfig {
        let mut spec = DatasetSpec::tiny();
        spec.cat_fields = 4; // 1 cont + 4 cat = 5 fields
        let model = ModelConfig::deep_ffm(5, 2, 1 << 10, &[8]);
        let mut cfg = DeployConfig::new(model, spec, mode);
        cfg.examples_per_round = 1500;
        cfg.holdout_examples = 800;
        cfg.serve = ServeConfig {
            workers: 2,
            max_batch: 32,
            max_wait_us: 100,
            context_cache_entries: 1024,
            max_group_candidates: 1024,
            ..ServeConfig::default()
        };
        cfg
    }

    #[test]
    fn rounds_publish_monotonic_versions_and_metrics() {
        let mut dl = DeploymentLoop::new(small_cfg(UpdateMode::QuantPatch));
        assert_eq!(dl.handle().version(), 1);
        let reports = dl.run_rounds(3).unwrap();
        assert_eq!(reports.len(), 3);
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(r.round, i);
            assert_eq!(r.version, 2 + i as u64); // v1 was the bootstrap
            assert_eq!(r.examples, 1500);
            assert!(r.update_bytes > 0);
            assert!(r.raw_bytes > 0);
            assert!(r.lag_seconds >= 0.0);
            assert!(r.holdout_auc.is_finite());
        }
        let m = dl.metrics();
        assert_eq!(m.rounds, 3);
        assert_eq!(m.examples, 4500);
        assert_eq!(m.last_version, 4);
        // steady-state quant+patch updates undercut raw files
        assert!(m.bandwidth_saving() > 1.0, "saving {}", m.bandwidth_saving());
        let stats = dl.shutdown();
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn served_model_tracks_trainer_within_mode_tolerance() {
        for mode in UpdateMode::ALL {
            let mut dl = DeploymentLoop::new(small_cfg(mode));
            dl.run_rounds(2).unwrap();
            let served = dl.handle().load();
            let trainer = dl.trainer();
            let max_err = served
                .pool
                .weights
                .iter()
                .zip(&trainer.pool.weights)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if mode.is_quantized() {
                assert!(max_err < 1e-3, "{mode:?} err {max_err}");
            } else {
                assert_eq!(max_err, 0.0, "{mode:?} must be lossless");
            }
            dl.shutdown();
        }
    }

    #[test]
    fn rounds_export_into_shared_registry() {
        use crate::obs::{ObsRegistry, RequestTracer, TraceSink};
        use std::sync::Arc;

        let reg = Arc::new(ObsRegistry::new());
        let obs = crate::obs::ObsOptions::with_registry(reg.clone())
            .tracer(RequestTracer::new(1, TraceSink::memory()));
        let mut dl =
            DeploymentLoop::with_obs(small_cfg(UpdateMode::QuantPatch), obs);
        dl.run_rounds(2).unwrap();

        assert_eq!(reg.gauge_value("fw_deploy_rounds"), Some(2.0));
        let lag = reg.gauge_value("fw_deploy_round_lag_seconds").unwrap();
        assert!(lag >= 0.0);
        let auc = reg.gauge_value("fw_deploy_holdout_auc").unwrap();
        assert!(auc.is_finite());
        let shipped = reg.counter_value("fw_deploy_update_bytes_total").unwrap();
        assert_eq!(shipped, dl.metrics().update_bytes_total);
        let swaps = reg.histogram_snapshot("fw_deploy_swap_ns").unwrap();
        assert_eq!(swaps.count(), 2);
        // the training chunks exported through the same registry
        assert_eq!(
            reg.counter_value("fw_train_examples_total"),
            Some(2 * 1500)
        );
        assert!(reg.gauge_value("fw_train_rolling_auc").is_some());

        // one render exposes serving + deploy + train series together
        let text = reg.render_prometheus();
        crate::testutil::check_prometheus_text(&text).expect("well-formed");
        assert!(text.contains("fw_deploy_swap_ns{quantile=\"0.99\"}"));
        assert!(text.contains("fw_serve_stage_total_ns"));
        assert!(text.contains("fw_train_examples_per_sec"));

        // every round traced exactly one deploy_swap event
        let tracer = dl.obs.tracer.clone().unwrap();
        tracer.flush();
        let events: Vec<String> = tracer
            .sink()
            .drain()
            .into_iter()
            .filter(|l| l.contains("\"deploy_swap\""))
            .collect();
        assert_eq!(events.len(), 2);
        let parsed = crate::util::json::parse(&events[1]).unwrap();
        assert_eq!(parsed.get("event").as_str(), Some("deploy_swap"));
        assert_eq!(parsed.get("round").as_f64(), Some(1.0));
        dl.shutdown();
    }

    #[test]
    fn checkpoint_restore_resumes_bit_identically() {
        for mode in [UpdateMode::QuantPatch, UpdateMode::Raw] {
            let cfg = small_cfg(mode); // train_threads defaults to 1
            // uninterrupted reference run
            let mut gold = DeploymentLoop::new(cfg.clone());
            gold.run_rounds(4).unwrap();
            // crashed run: auto-checkpoint after round 2, kill, restore
            let path = std::env::temp_dir().join(format!(
                "fw_deploy_ckpt_{}_{mode:?}.ckpt",
                std::process::id()
            ));
            let mut cfg2 = cfg.clone();
            cfg2.checkpoint_every_rounds = 2;
            cfg2.checkpoint_path = Some(path.clone());
            let mut dl = DeploymentLoop::new(cfg2.clone());
            dl.run_rounds(2).unwrap();
            dl.shutdown(); // the crash
            let mut dl = DeploymentLoop::restore_from_path(
                cfg2,
                ObsOptions::default(),
                &path,
            )
            .unwrap();
            assert_eq!(dl.rounds_run(), 2, "{mode:?}");
            assert_eq!(dl.handle().version(), 3, "{mode:?}"); // v1 + 2 swaps
            dl.run_rounds(2).unwrap();
            // trainer, served weights, version line, and byte ledger all
            // land exactly where the uninterrupted run did
            assert_eq!(
                dl.trainer().pool.weights,
                gold.trainer().pool.weights,
                "{mode:?} trainer diverged"
            );
            assert_eq!(dl.handle().version(), gold.handle().version());
            assert_eq!(
                dl.handle().load().pool.weights,
                gold.handle().load().pool.weights,
                "{mode:?} served model diverged"
            );
            assert_eq!(
                dl.pipeline().sent_bytes(),
                gold.pipeline().sent_bytes(),
                "{mode:?} pipeline base diverged"
            );
            let (ma, mb) = (dl.metrics().clone(), gold.metrics().clone());
            assert_eq!(ma.rounds, 4);
            assert_eq!(ma.update_bytes_total, mb.update_bytes_total);
            assert_eq!(ma.raw_bytes_total, mb.raw_bytes_total);
            // recovery time is observable where the chaos soak looks
            let reg = dl.engine().obs_registry().clone();
            let h = reg.histogram_snapshot("fw_recovery_replay_ns").unwrap();
            assert_eq!(h.count(), 1, "{mode:?}");
            let _ = std::fs::remove_file(&path);
            dl.shutdown();
            gold.shutdown();
        }
    }

    #[test]
    fn deploy_checkpoint_payload_roundtrips() {
        let mut dl = DeploymentLoop::new(small_cfg(UpdateMode::QuantPatch));
        dl.run_rounds(1).unwrap();
        let ckpt = dl.checkpoint();
        let back = DeployCheckpoint::from_bytes(&ckpt.to_bytes()).unwrap();
        assert_eq!(back.mode, ckpt.mode);
        assert_eq!(back.round, 1);
        assert_eq!(back.examples_consumed, 1500);
        assert_eq!(back.version, 2);
        assert_eq!(back.trainer, ckpt.trainer);
        assert_eq!(back.receiver_base, ckpt.receiver_base);
        assert_eq!(back.metrics.update_bytes_total, ckpt.metrics.update_bytes_total);
        // a fabric checkpoint payload is refused by its version byte
        let mut bad = ckpt.to_bytes();
        bad[0] = 1;
        assert!(DeployCheckpoint::from_bytes(&bad).is_err());
        dl.shutdown();
    }

    #[test]
    fn before_swap_hook_sees_next_version() {
        let mut dl = DeploymentLoop::new(small_cfg(UpdateMode::Raw));
        let mut observed = None;
        dl.run_round_with(|reg, v| {
            observed = Some((reg.pool.weights.len(), v));
        })
        .unwrap();
        let (n, v) = observed.expect("hook ran");
        assert_eq!(v, 2);
        assert_eq!(n, dl.trainer().num_weights());
        assert_eq!(dl.handle().version(), 2);
        dl.shutdown();
    }
}
