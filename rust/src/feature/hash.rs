//! Feature hashing — MurmurHash3 (x86 32-bit finalizer variant), the
//! same family VW and Fwumious Wabbit use, so hashed models are stable
//! across runs, machines and releases (a requirement for the byte-level
//! weight patcher: identical feature→bucket mapping keeps weight files
//! structurally aligned between training rounds).

const C1: u32 = 0xcc9e2d51;
const C2: u32 = 0x1b873593;

#[inline]
fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85ebca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2ae35);
    h ^= h >> 16;
    h
}

/// MurmurHash3 x86_32.
pub fn murmur3_32(data: &[u8], seed: u32) -> u32 {
    let mut h = seed;
    let chunks = data.chunks_exact(4);
    let tail = chunks.remainder();
    for chunk in chunks {
        let mut k = u32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        k = k.wrapping_mul(C1).rotate_left(15).wrapping_mul(C2);
        h ^= k;
        h = h.rotate_left(13).wrapping_mul(5).wrapping_add(0xe6546b64);
    }
    let mut k: u32 = 0;
    for (i, &b) in tail.iter().enumerate() {
        k |= (b as u32) << (8 * i);
    }
    if !tail.is_empty() {
        k = k.wrapping_mul(C1).rotate_left(15).wrapping_mul(C2);
        h ^= k;
    }
    h ^= data.len() as u32;
    fmix32(h)
}

/// Streaming MurmurHash3 x86_32 over whole little-endian `u32` words.
///
/// Hashing N words through [`push_u32`](Self::push_u32) followed by
/// [`finish`](Self::finish) is bit-identical to [`murmur3_32`] over the
/// words' concatenated LE bytes — a `u32` *is* one murmur block, so the
/// hot serving path (context→shard affinity) can hash buckets with zero
/// allocation and zero byte shuffling.
#[derive(Clone, Copy, Debug)]
pub struct Murmur3x32 {
    h: u32,
    len: u32,
}

impl Murmur3x32 {
    #[inline]
    pub fn new(seed: u32) -> Self {
        Murmur3x32 { h: seed, len: 0 }
    }

    /// Absorb one word (one full 4-byte murmur block).
    #[inline]
    pub fn push_u32(&mut self, word: u32) {
        let k = word.wrapping_mul(C1).rotate_left(15).wrapping_mul(C2);
        self.h ^= k;
        self.h = self.h.rotate_left(13).wrapping_mul(5).wrapping_add(0xe6546b64);
        self.len = self.len.wrapping_add(4);
    }

    /// Finalize (the stream length is part of the hash).
    #[inline]
    pub fn finish(&self) -> u32 {
        fmix32(self.h ^ self.len)
    }
}

/// Hash a (namespace, feature-name) pair into the model bucket space.
/// The namespace seed keeps identical tokens in different fields from
/// colliding systematically.
#[inline]
pub fn feature_bucket(namespace_seed: u32, token: &str, mask: u32) -> u32 {
    murmur3_32(token.as_bytes(), namespace_seed) & mask
}

/// Hash a raw integer id (synthetic data path) into the bucket space.
#[inline]
pub fn id_bucket(namespace_seed: u32, id: u64, mask: u32) -> u32 {
    murmur3_32(&id.to_le_bytes(), namespace_seed) & mask
}

/// Derive a per-namespace seed from its single-char name.
#[inline]
pub fn namespace_seed(name: &str) -> u32 {
    murmur3_32(name.as_bytes(), 0x5eed_5eed)
}

/// Combine two bucket hashes (quadratic/interacting namespaces).
#[inline]
pub fn combine(a: u32, b: u32, mask: u32) -> u32 {
    // 32-bit mix of the pair, VW-style multiply-shift.
    let x = (a as u64).wrapping_mul(0x9e3779b97f4a7c15) ^ (b as u64);
    ((x ^ (x >> 29)) as u32) & mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn murmur_known_vectors() {
        // Reference vectors for MurmurHash3 x86_32.
        assert_eq!(murmur3_32(b"", 0), 0);
        assert_eq!(murmur3_32(b"", 1), 0x514E28B7);
        assert_eq!(murmur3_32(b"abcd", 0x9747b28c), 0xF0478627);
        assert_eq!(murmur3_32(b"Hello, world!", 0x9747b28c), 0x24884CBA);
    }

    #[test]
    fn deterministic_and_masked() {
        let mask = (1 << 18) - 1;
        let a = feature_bucket(7, "user=123", mask);
        let b = feature_bucket(7, "user=123", mask);
        assert_eq!(a, b);
        assert!(a <= mask);
    }

    #[test]
    fn namespace_seed_separates_fields() {
        let mask = (1 << 20) - 1;
        let s1 = namespace_seed("A");
        let s2 = namespace_seed("B");
        assert_ne!(s1, s2);
        let collisions = (0..1000)
            .filter(|i| {
                feature_bucket(s1, &format!("f{i}"), mask)
                    == feature_bucket(s2, &format!("f{i}"), mask)
            })
            .count();
        assert!(collisions < 5, "systematic collisions: {collisions}");
    }

    #[test]
    fn spread_over_buckets() {
        let mask = 1023;
        let mut hist = [0u32; 1024];
        for i in 0..100_000u64 {
            hist[id_bucket(3, i, mask) as usize] += 1;
        }
        let max = *hist.iter().max().unwrap();
        let min = *hist.iter().min().unwrap();
        assert!(min > 40 && max < 200, "min={min} max={max}");
    }

    #[test]
    fn combine_depends_on_order() {
        let mask = u32::MAX;
        assert_ne!(combine(1, 2, mask), combine(2, 1, mask));
    }

    #[test]
    fn streaming_u32_matches_byte_hash() {
        let mut rng = crate::util::rng::Pcg32::seeded(0x51ea);
        for n in 0..64usize {
            let words: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let mut bytes = Vec::with_capacity(n * 4);
            for &w in &words {
                bytes.extend_from_slice(&w.to_le_bytes());
            }
            for seed in [0u32, 1, 0x5a5a, 0x9747b28c] {
                let mut m = Murmur3x32::new(seed);
                for &w in &words {
                    m.push_u32(w);
                }
                assert_eq!(
                    m.finish(),
                    murmur3_32(&bytes, seed),
                    "n={n} seed={seed:#x}"
                );
            }
        }
    }
}
