//! Neural block (the gray block of Figure 2): an MLP over the
//! MergeNormLayer output, ReLU activations, scalar head.
//!
//! Implements §4.3 — **sparse weight updates**: "by realizing that we
//! can identify *zero global gradient* scenarios upfront, prior to
//! updating any weights, we could skip whole branches of computation
//! with no impact on learning. [...] This optimization was possible due
//! to ReLU's nature; this activation maps weights to zeros, effectively
//! enabling identification of compute branches that need to be skipped
//! during updates."
//!
//! Concretely, in `backward`:
//! * units with ReLU output 0 have zero pre-activation gradient — their
//!   bias, their entire incoming weight column, and their contribution
//!   to upstream gradients are skipped;
//! * inputs that are 0 (frequent: the previous layer is also ReLU) get
//!   their whole weight *row* update skipped;
//! * if a layer has no active units at all, the entire remaining
//!   backward branch is cut.
//!
//! `sparse: false` runs the same math without the skips (the control
//! arm of Table 3).
//!
//! All dense math routes through the [`crate::simd`] dispatchers
//! (`matvec_rowmajor`, `matmul_rowmajor`, the transposed GEMM pair), so
//! this block picks up whichever rung of the scalar → AVX2+FMA →
//! AVX-512 ladder the host offers without any code here caring.

use crate::model::optimizer::UpdateRule;
use crate::model::weights::{LayerLayout, Layout};
use crate::model::BatchGradBufs;
use crate::simd::dot;
use crate::util::math::relu;

/// The MLP + head, operating on slices of the shared weight pool.
#[derive(Clone, Debug)]
pub struct NeuralBlock {
    pub layers: Vec<LayerLayout>,
    pub w_out_off: usize,
    pub w_out_len: usize,
    pub b_out_off: usize,
    /// §4.3 sparse updates on/off.
    pub sparse: bool,
    /// Scratch: active-unit indices per layer (reused across calls).
    active_scratch: Vec<Vec<u32>>,
}

impl NeuralBlock {
    pub fn new(layout: &Layout, sparse: bool) -> Self {
        NeuralBlock {
            layers: layout.layers.clone(),
            w_out_off: layout.w_out_off,
            w_out_len: layout.w_out_len,
            b_out_off: layout.b_out_off,
            sparse,
            active_scratch: vec![Vec::new(); layout.layers.len()],
        }
    }

    /// Forward pass.  `activations[l]` receives layer `l`'s ReLU
    /// output; returns the scalar head value.
    pub fn forward(
        &self,
        weights: &[f32],
        input: &[f32],
        activations: &mut Vec<Vec<f32>>,
    ) -> f32 {
        activations.resize(self.layers.len(), Vec::new());
        for (l, lay) in self.layers.iter().enumerate() {
            let (prev, cur) = activations.split_at_mut(l);
            let x: &[f32] = if l == 0 { input } else { &prev[l - 1] };
            debug_assert_eq!(x.len(), lay.rows);
            let out = &mut cur[0];
            out.resize(lay.cols, 0.0);
            let w = &weights[lay.w_off..lay.w_off + lay.rows * lay.cols];
            let b = &weights[lay.b_off..lay.b_off + lay.cols];
            dot::matvec_rowmajor(x, w, Some(b), out);
            for v in out.iter_mut() {
                *v = relu(*v);
            }
        }
        let x: &[f32] = match activations.last() {
            Some(last) => last,
            None => input,
        };
        let w_out = &weights[self.w_out_off..self.w_out_off + self.w_out_len];
        dot::dot(x, w_out) + weights[self.b_out_off]
    }

    /// Batched forward pass over `batch` input rows laid out back to
    /// back (`batch × merged_dim`).  `activations[l]` receives layer
    /// `l`'s ReLU output batch-strided (`batch × cols`); `heads`
    /// receives the scalar head value per row.
    ///
    /// Each weight matrix is streamed once per 4-candidate register
    /// block (see [`crate::simd::batch::matmul_rowmajor`]) instead of
    /// once per candidate; per-row results are bit-identical to scoring
    /// the row alone.
    pub fn forward_batch(
        &self,
        weights: &[f32],
        input: &[f32],
        batch: usize,
        activations: &mut Vec<Vec<f32>>,
        heads: &mut Vec<f32>,
    ) {
        activations.resize(self.layers.len(), Vec::new());
        for (l, lay) in self.layers.iter().enumerate() {
            let (prev, cur) = activations.split_at_mut(l);
            let x: &[f32] = if l == 0 { input } else { &prev[l - 1] };
            debug_assert_eq!(x.len(), batch * lay.rows);
            let out = &mut cur[0];
            out.resize(batch * lay.cols, 0.0);
            let w = &weights[lay.w_off..lay.w_off + lay.rows * lay.cols];
            let b = &weights[lay.b_off..lay.b_off + lay.cols];
            crate::simd::batch::matmul_rowmajor(
                x,
                batch,
                w,
                lay.rows,
                lay.cols,
                Some(b),
                out,
            );
            for v in out.iter_mut() {
                *v = relu(*v);
            }
        }
        let (x, width): (&[f32], usize) = match self.layers.last() {
            Some(lay) => (activations[self.layers.len() - 1].as_slice(), lay.cols),
            None => (input, input.len() / batch.max(1)),
        };
        let w_out = &weights[self.w_out_off..self.w_out_off + self.w_out_len];
        let b_out = weights[self.b_out_off];
        debug_assert_eq!(width, self.w_out_len);
        heads.clear();
        heads.reserve(batch);
        for row in x.chunks_exact(width).take(batch) {
            heads.push(dot::dot(row, w_out) + b_out);
        }
    }

    /// Backward pass + in-place updates.
    ///
    /// * `d_head` — dL/d(head output).
    /// * `dinput` — receives dL/d(block input).
    ///
    /// Returns the number of weight updates applied (the Table-3
    /// speedup is visible directly in this count).
    #[allow(clippy::too_many_arguments)]
    pub fn backward<U: UpdateRule>(
        &mut self,
        weights: &mut [f32],
        acc: &mut [f32],
        input: &[f32],
        activations: &[Vec<f32>],
        d_head: f32,
        dinput: &mut [f32],
        grad_bufs: &mut Vec<Vec<f32>>,
        rule: &mut U,
    ) -> usize {
        let nl = self.layers.len();
        grad_bufs.resize(nl, Vec::new());
        let mut updates = 0usize;

        // Head: dh_last = d_head * w_out (pre-update), then update head.
        let last = if nl == 0 { input } else { &activations[nl - 1] };
        let mut dh: Vec<f32> = weights
            [self.w_out_off..self.w_out_off + self.w_out_len]
            .iter()
            .map(|&w| d_head * w)
            .collect();
        for (j, &hj) in last.iter().enumerate() {
            if !self.sparse || hj != 0.0 {
                let idx = self.w_out_off + j;
                rule.update(idx, &mut weights[idx], &mut acc[idx], d_head * hj);
                updates += 1;
            }
        }
        {
            let idx = self.b_out_off;
            rule.update(idx, &mut weights[idx], &mut acc[idx], d_head);
            updates += 1;
        }
        if nl == 0 {
            dinput.copy_from_slice(&dh);
            return updates;
        }

        // Hidden layers, last to first.
        for l in (0..nl).rev() {
            let lay = self.layers[l];
            let h = &activations[l];
            let x: &[f32] = if l == 0 { input } else { &activations[l - 1] };

            // ReLU gate -> pre-activation gradient; collect active units.
            let mut active = std::mem::take(&mut self.active_scratch[l]);
            active.clear();
            let mut dpre = std::mem::take(&mut grad_bufs[l]);
            dpre.resize(lay.cols, 0.0);
            for j in 0..lay.cols {
                if h[j] > 0.0 {
                    dpre[j] = dh[j];
                    if dh[j] != 0.0 {
                        active.push(j as u32);
                    }
                } else {
                    dpre[j] = 0.0;
                }
            }

            let dx_needed = l > 0 || !dinput.is_empty();
            let mut dx = vec![0f32; lay.rows];

            if self.sparse {
                // §4.3: zero global gradient -> cut the whole branch.
                if active.is_empty() {
                    self.active_scratch[l] = active;
                    grad_bufs[l] = dpre;
                    if dx_needed && l == 0 {
                        dinput.fill(0.0);
                    }
                    // upstream layers receive zero gradient: done.
                    if l == 0 {
                        return updates;
                    }
                    dh = dx; // all zeros propagate
                    continue;
                }
                for i in 0..lay.rows {
                    let row = lay.w_off + i * lay.cols;
                    let xi = x[i];
                    // dx[i] = Σ_active W[i,j] dpre[j] (pre-update W)
                    if dx_needed {
                        let mut s = 0.0f32;
                        for &ju in &active {
                            s += weights[row + ju as usize] * dpre[ju as usize];
                        }
                        dx[i] = s;
                    }
                    // row update only when the input is non-zero
                    if xi != 0.0 {
                        for &ju in &active {
                            let idx = row + ju as usize;
                            rule.update(
                                idx,
                                &mut weights[idx],
                                &mut acc[idx],
                                xi * dpre[ju as usize],
                            );
                            updates += 1;
                        }
                    }
                }
                for &ju in &active {
                    let idx = lay.b_off + ju as usize;
                    rule.update(idx, &mut weights[idx], &mut acc[idx], dpre[ju as usize]);
                    updates += 1;
                }
            } else {
                // Dense control: touch every coordinate.
                for i in 0..lay.rows {
                    let row = lay.w_off + i * lay.cols;
                    let xi = x[i];
                    if dx_needed {
                        dx[i] = dot::dot(&weights[row..row + lay.cols], &dpre);
                    }
                    for j in 0..lay.cols {
                        let idx = row + j;
                        rule.update(idx, &mut weights[idx], &mut acc[idx], xi * dpre[j]);
                        updates += 1;
                    }
                }
                for j in 0..lay.cols {
                    let idx = lay.b_off + j;
                    rule.update(idx, &mut weights[idx], &mut acc[idx], dpre[j]);
                    updates += 1;
                }
            }

            self.active_scratch[l] = active;
            grad_bufs[l] = dpre;
            if l == 0 {
                dinput.copy_from_slice(&dx);
            } else {
                dh = dx;
            }
        }
        updates
    }

    /// Batched backward + in-place updates over a micro-batch.
    ///
    /// Consumes the batch-strided activations produced by
    /// [`forward_batch`](Self::forward_batch).  Each layer's weight
    /// gradient is reduced over the whole micro-batch by the
    /// transposed-operand GEMM pair
    /// ([`matmul_transposed`](crate::simd::batch::matmul_transposed)
    /// for `dX = dY·Wᵀ`,
    /// [`matmul_xt_dy`](crate::simd::batch::matmul_xt_dy) for
    /// `dW += Xᵀ·dY`) and applied through `rule` **once per coordinate
    /// per micro-batch** — minibatch semantics: all gradients are taken
    /// at batch-start weights and the B per-example optimizer steps
    /// collapse into one summed step.  With `batch == 1` the math is
    /// the per-example backward's (same gradients, one step).
    ///
    /// §4.3 sparse skips apply at micro-batch granularity: a coordinate
    /// is skipped when its batch-summed gradient is exactly zero, and a
    /// layer with no live (ReLU-active, nonzero-gradient) unit in *any*
    /// row cuts the whole remaining branch.
    ///
    /// * `d_heads` — per-row dL/d(head output) (`B` values).
    /// * `dinput` — receives batch-strided dL/d(block input)
    ///   (`B × rows₀`).
    ///
    /// Returns the number of weight updates applied.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_batch<U: UpdateRule>(
        &mut self,
        weights: &mut [f32],
        acc: &mut [f32],
        input: &[f32],
        batch: usize,
        activations: &[Vec<f32>],
        d_heads: &[f32],
        dinput: &mut [f32],
        bufs: &mut BatchGradBufs,
        rule: &mut U,
    ) -> usize {
        debug_assert_eq!(d_heads.len(), batch);
        let nl = self.layers.len();
        let width = self.w_out_len;
        let mut updates = 0usize;

        // Head: dh[b, j] = d_b * w_out[j] (pre-update weights), then
        // one summed update per head coordinate.
        let last: &[f32] = if nl == 0 { input } else { &activations[nl - 1] };
        debug_assert_eq!(last.len(), batch * width);
        bufs.dh.resize(batch * width, 0.0);
        let w_out = &weights[self.w_out_off..self.w_out_off + width];
        for (dhr, &db) in bufs.dh.chunks_exact_mut(width).zip(d_heads) {
            for (dhv, &wv) in dhr.iter_mut().zip(w_out) {
                *dhv = db * wv;
            }
        }
        for j in 0..width {
            let mut g = 0.0f32;
            for (b, &db) in d_heads.iter().enumerate() {
                g += db * last[b * width + j];
            }
            if !self.sparse || g != 0.0 {
                let idx = self.w_out_off + j;
                rule.update(idx, &mut weights[idx], &mut acc[idx], g);
                updates += 1;
            }
        }
        {
            let g: f32 = d_heads.iter().sum();
            let idx = self.b_out_off;
            rule.update(idx, &mut weights[idx], &mut acc[idx], g);
            updates += 1;
        }
        if nl == 0 {
            dinput.copy_from_slice(&bufs.dh[..batch * width]);
            return updates;
        }

        // Hidden layers, last to first.  bufs.dh holds the batch-
        // strided upstream gradient dL/d(layer output); the ReLU gate
        // turns it into dpre in place.
        for l in (0..nl).rev() {
            let lay = self.layers[l];
            let h = &activations[l];
            let x: &[f32] = if l == 0 { input } else { &activations[l - 1] };
            debug_assert_eq!(x.len(), batch * lay.rows);
            let dpre = &mut bufs.dh[..batch * lay.cols];
            let mut any_active = false;
            for (dp, &hv) in dpre.iter_mut().zip(&h[..batch * lay.cols]) {
                if hv > 0.0 {
                    if *dp != 0.0 {
                        any_active = true;
                    }
                } else {
                    *dp = 0.0;
                }
            }
            bufs.dx.clear();
            bufs.dx.resize(batch * lay.rows, 0.0);
            if self.sparse && !any_active {
                // §4.3: zero global gradient across the whole micro-
                // batch -> cut the branch (upstream gradient all-zero).
                if l == 0 {
                    dinput.fill(0.0);
                    return updates;
                }
                std::mem::swap(&mut bufs.dh, &mut bufs.dx);
                continue;
            }
            let w = &weights[lay.w_off..lay.w_off + lay.rows * lay.cols];
            // dX = dpre · Wᵀ (pre-update weights)
            crate::simd::batch::matmul_transposed(
                dpre,
                batch,
                w,
                lay.rows,
                lay.cols,
                &mut bufs.dx,
            );
            // dW += Xᵀ · dpre, reduced over the micro-batch
            bufs.wgrad.clear();
            bufs.wgrad.resize(lay.rows * lay.cols, 0.0);
            crate::simd::batch::matmul_xt_dy(
                x,
                batch,
                dpre,
                lay.rows,
                lay.cols,
                &mut bufs.wgrad,
            );
            for (off, &g) in bufs.wgrad.iter().enumerate() {
                if !self.sparse || g != 0.0 {
                    let idx = lay.w_off + off;
                    rule.update(idx, &mut weights[idx], &mut acc[idx], g);
                    updates += 1;
                }
            }
            for j in 0..lay.cols {
                let mut g = 0.0f32;
                for b in 0..batch {
                    g += dpre[b * lay.cols + j];
                }
                if !self.sparse || g != 0.0 {
                    let idx = lay.b_off + j;
                    rule.update(idx, &mut weights[idx], &mut acc[idx], g);
                    updates += 1;
                }
            }
            if l == 0 {
                dinput.copy_from_slice(&bufs.dx);
            } else {
                std::mem::swap(&mut bufs.dh, &mut bufs.dx);
            }
        }
        updates
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::optimizer::GradRecorder;
    use crate::model::weights::{Layout, WeightPool};
    use crate::util::rng::Pcg32;

    fn setup(hidden: &[usize]) -> (ModelConfig, Layout, WeightPool) {
        let cfg = ModelConfig::deep_ffm(4, 2, 16, hidden);
        let layout = Layout::new(&cfg);
        let pool = WeightPool::init(&cfg, &layout);
        (cfg, layout, pool)
    }

    fn rand_input(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| rng.normal() * 0.5).collect()
    }

    #[test]
    fn forward_manual_single_layer() {
        let (cfg, layout, mut pool) = setup(&[3]);
        let d = cfg.merged_dim();
        // deterministic weights
        for (i, w) in pool.weights.iter_mut().enumerate() {
            *w = ((i % 7) as f32 - 3.0) * 0.1;
        }
        let nb = NeuralBlock::new(&layout, true);
        let x = rand_input(d, 3);
        let mut acts = Vec::new();
        let head = nb.forward(&pool.weights, &x, &mut acts);
        // manual
        let lay = layout.layers[0];
        let mut h = vec![0f32; 3];
        for j in 0..3 {
            let mut s = pool.weights[lay.b_off + j];
            for i in 0..d {
                s += x[i] * pool.weights[lay.w_off + i * 3 + j];
            }
            h[j] = s.max(0.0);
        }
        let mut want = pool.weights[layout.b_out_off];
        for j in 0..3 {
            want += h[j] * pool.weights[layout.w_out_off + j];
        }
        assert!((head - want).abs() < 1e-5);
        assert_eq!(acts[0], h);
    }

    #[test]
    fn forward_batch_matches_sequential_rows() {
        for hidden in [&[6usize][..], &[16, 8][..], &[32][..]] {
            let (cfg, layout, mut pool) = setup(hidden);
            let d = cfg.merged_dim();
            let mut rng = Pcg32::seeded(41);
            for w in pool.weights.iter_mut() {
                *w = rng.normal() * 0.4;
            }
            let nb = NeuralBlock::new(&layout, true);
            let batch = 7usize;
            let input = rand_input(batch * d, 19);
            let mut acts_b = Vec::new();
            let mut heads = Vec::new();
            nb.forward_batch(&pool.weights, &input, batch, &mut acts_b, &mut heads);
            assert_eq!(heads.len(), batch);
            for b in 0..batch {
                let mut acts = Vec::new();
                let head =
                    nb.forward(&pool.weights, &input[b * d..(b + 1) * d], &mut acts);
                assert!(
                    (head - heads[b]).abs() < 1e-5 * (1.0 + head.abs()),
                    "hidden={hidden:?} row {b}: {head} vs {}",
                    heads[b]
                );
                for (l, a) in acts.iter().enumerate() {
                    let cols = layout.layers[l].cols;
                    for (j, v) in a.iter().enumerate() {
                        let got = acts_b[l][b * cols + j];
                        assert!(
                            (v - got).abs() < 1e-5 * (1.0 + v.abs()),
                            "layer {l} row {b} unit {j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn backward_batch_matches_per_example_grads() {
        // Batched minibatch backward == sum of per-example backwards at
        // the same (frozen) weights, for every architecture depth.
        for hidden in [&[6usize][..], &[16, 8][..], &[32][..]] {
            let (cfg, layout, mut pool) = setup(hidden);
            let d = cfg.merged_dim();
            let mut rng = Pcg32::seeded(51);
            for w in pool.weights.iter_mut() {
                *w = rng.normal() * 0.4;
            }
            let batch = 5usize;
            let input = rand_input(batch * d, 23);
            let d_heads: Vec<f32> =
                (0..batch).map(|b| 0.3 + 0.17 * b as f32).collect();
            let mut nb = NeuralBlock::new(&layout, true);
            let mut acts_b = Vec::new();
            let mut heads = Vec::new();
            nb.forward_batch(&pool.weights, &input, batch, &mut acts_b, &mut heads);
            let mut w = pool.weights.clone();
            let mut acc = pool.acc.clone();
            let mut rec = GradRecorder::default();
            let mut dinput_b = vec![0f32; batch * d];
            let mut bufs = BatchGradBufs::default();
            nb.backward_batch(
                &mut w,
                &mut acc,
                &input,
                batch,
                &acts_b,
                &d_heads,
                &mut dinput_b,
                &mut bufs,
                &mut rec,
            );
            assert_eq!(w, pool.weights, "recorder must not mutate weights");
            let batched = rec.dense(layout.total);
            let mut per = vec![0f32; layout.total];
            for b in 0..batch {
                let x = &input[b * d..(b + 1) * d];
                let mut nb1 = NeuralBlock::new(&layout, true);
                let mut acts = Vec::new();
                nb1.forward(&pool.weights, x, &mut acts);
                let mut w1 = pool.weights.clone();
                let mut acc1 = pool.acc.clone();
                let mut rec1 = GradRecorder::default();
                let mut dinput = vec![0f32; d];
                let mut gb = Vec::new();
                nb1.backward(
                    &mut w1, &mut acc1, x, &acts, d_heads[b], &mut dinput, &mut gb,
                    &mut rec1,
                );
                for (p, g) in per.iter_mut().zip(rec1.dense(layout.total)) {
                    *p += g;
                }
                for i in 0..d {
                    let got = dinput_b[b * d + i];
                    assert!(
                        (got - dinput[i]).abs() < 1e-4 * (1.0 + dinput[i].abs()),
                        "hidden={hidden:?} row {b} dinput[{i}]: {got} vs {}",
                        dinput[i]
                    );
                }
            }
            for i in 0..layout.total {
                assert!(
                    (batched[i] - per[i]).abs() < 1e-4 * (1.0 + per[i].abs()),
                    "hidden={hidden:?} grad {i}: {} vs {}",
                    batched[i],
                    per[i]
                );
            }
        }
    }

    #[test]
    fn backward_batch_sparse_and_dense_agree() {
        let (cfg, layout, mut pool) = setup(&[16, 16]);
        let d = cfg.merged_dim();
        let mut rng = Pcg32::seeded(53);
        for w in pool.weights.iter_mut() {
            *w = rng.normal() * 0.4;
        }
        let batch = 4usize;
        let input = rand_input(batch * d, 29);
        let d_heads = vec![0.9f32, -0.4, 0.25, 1.3];
        let run = |sparse: bool| -> (Vec<f32>, usize) {
            let mut nb = NeuralBlock::new(&layout, sparse);
            let mut acts = Vec::new();
            let mut heads = Vec::new();
            nb.forward_batch(&pool.weights, &input, batch, &mut acts, &mut heads);
            let mut w = pool.weights.clone();
            let mut acc = pool.acc.clone();
            let mut rec = GradRecorder::default();
            let mut dinput = vec![0f32; batch * d];
            let mut bufs = BatchGradBufs::default();
            let n = nb.backward_batch(
                &mut w, &mut acc, &input, batch, &acts, &d_heads, &mut dinput,
                &mut bufs, &mut rec,
            );
            (rec.dense(layout.total), n)
        };
        let (gs, ns) = run(true);
        let (gd, nd) = run(false);
        for i in 0..gs.len() {
            assert!((gs[i] - gd[i]).abs() < 1e-5, "grad {i}: {} vs {}", gs[i], gd[i]);
        }
        assert!(ns < nd, "sparse={ns} dense={nd}");
    }

    #[test]
    fn backward_batch_dead_layer_cuts_branch() {
        let (cfg, layout, mut pool) = setup(&[4]);
        let d = cfg.merged_dim();
        let lay = layout.layers[0];
        for j in 0..lay.cols {
            pool.weights[lay.b_off + j] = -100.0;
        }
        let batch = 3usize;
        let input = rand_input(batch * d, 31);
        let mut nb = NeuralBlock::new(&layout, true);
        let mut acts = Vec::new();
        let mut heads = Vec::new();
        nb.forward_batch(&pool.weights, &input, batch, &mut acts, &mut heads);
        let mut w = pool.weights.clone();
        let mut acc = pool.acc.clone();
        let mut rec = GradRecorder::default();
        let mut dinput = vec![0f32; batch * d];
        let mut bufs = BatchGradBufs::default();
        let n = nb.backward_batch(
            &mut w,
            &mut acc,
            &input,
            batch,
            &acts,
            &[1.0, -0.5, 0.75],
            &mut dinput,
            &mut bufs,
            &mut rec,
        );
        // all hidden activations are zero -> only b_out updates (the
        // head weights see an exactly-zero summed gradient)
        assert!(n <= 2, "updates={n}");
        assert!(dinput.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gradients_match_finite_difference_two_layers() {
        let (cfg, layout, pool) = setup(&[6, 4]);
        let d = cfg.merged_dim();
        let x = rand_input(d, 7);
        let f = |w: &[f32]| -> f32 {
            let nb = NeuralBlock::new(&layout, true);
            let mut acts = Vec::new();
            nb.forward(w, &x, &mut acts)
        };
        let w0 = pool.weights.clone();
        let mut w = w0.clone();
        let mut acc = pool.acc.clone();
        let mut nb = NeuralBlock::new(&layout, true);
        let mut acts = Vec::new();
        nb.forward(&w, &x, &mut acts);
        let mut rec = GradRecorder::default();
        let mut dinput = vec![0f32; d];
        let mut bufs = Vec::new();
        nb.backward(&mut w, &mut acc, &x, &acts, 1.0, &mut dinput, &mut bufs, &mut rec);
        assert_eq!(w, w0);
        let analytic = rec.dense(layout.total);
        let eps = 1e-3;
        // check a sample of weight coords incl. both layers + head
        let lay0 = layout.layers[0];
        let lay1 = layout.layers[1];
        let coords = [
            lay0.w_off,
            lay0.w_off + 5,
            lay0.b_off + 1,
            lay1.w_off + 3,
            lay1.b_off,
            layout.w_out_off + 2,
            layout.b_out_off,
        ];
        for &idx in &coords {
            let mut wp = w0.clone();
            wp[idx] += eps;
            let mut wm = w0.clone();
            wm[idx] -= eps;
            let numeric = (f(&wp) - f(&wm)) / (2.0 * eps);
            assert!(
                (numeric - analytic[idx]).abs() < 2e-2 * (1.0 + numeric.abs()),
                "idx={idx} numeric={numeric} analytic={}",
                analytic[idx]
            );
        }
        // input gradient
        for i in [0usize, d / 2, d - 1] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fi = |xx: &Vec<f32>| {
                let nb = NeuralBlock::new(&layout, true);
                let mut acts = Vec::new();
                nb.forward(&w0, xx, &mut acts)
            };
            let numeric = (fi(&xp) - fi(&xm)) / (2.0 * eps);
            assert!(
                (numeric - dinput[i]).abs() < 2e-2 * (1.0 + numeric.abs()),
                "input {i}: numeric={numeric} analytic={}",
                dinput[i]
            );
        }
    }

    #[test]
    fn sparse_and_dense_agree() {
        let (cfg, layout, pool) = setup(&[8, 5]);
        let d = cfg.merged_dim();
        let x = rand_input(d, 11);
        let run = |sparse: bool| -> (Vec<f32>, Vec<f32>) {
            let mut w = pool.weights.clone();
            let mut acc = pool.acc.clone();
            let mut nb = NeuralBlock::new(&layout, sparse);
            let mut acts = Vec::new();
            nb.forward(&w, &x, &mut acts);
            let mut rec = GradRecorder::default();
            let mut dinput = vec![0f32; d];
            let mut bufs = Vec::new();
            nb.backward(&mut w, &mut acc, &x, &acts, 0.7, &mut dinput, &mut bufs, &mut rec);
            (rec.dense(layout.total), dinput)
        };
        let (gs, dis) = run(true);
        let (gd, did) = run(false);
        for i in 0..gs.len() {
            assert!((gs[i] - gd[i]).abs() < 1e-5, "grad {i}: {} vs {}", gs[i], gd[i]);
        }
        for i in 0..dis.len() {
            assert!((dis[i] - did[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn sparse_applies_fewer_updates() {
        let (cfg, layout, pool) = setup(&[16, 16]);
        let d = cfg.merged_dim();
        let x = rand_input(d, 13);
        let count = |sparse: bool| -> usize {
            let mut w = pool.weights.clone();
            let mut acc = pool.acc.clone();
            let mut nb = NeuralBlock::new(&layout, sparse);
            let mut acts = Vec::new();
            nb.forward(&w, &x, &mut acts);
            let mut rec = GradRecorder::default();
            let mut dinput = vec![0f32; d];
            let mut bufs = Vec::new();
            nb.backward(&mut w, &mut acc, &x, &acts, 1.0, &mut dinput, &mut bufs, &mut rec)
        };
        let dense = count(false);
        let sparse = count(true);
        assert!(sparse < dense, "sparse={sparse} dense={dense}");
    }

    #[test]
    fn dead_layer_cuts_branch() {
        let (cfg, layout, mut pool) = setup(&[4]);
        let d = cfg.merged_dim();
        // Force all hidden pre-activations negative: big negative biases.
        let lay = layout.layers[0];
        for j in 0..lay.cols {
            pool.weights[lay.b_off + j] = -100.0;
        }
        let x = rand_input(d, 17);
        let mut w = pool.weights.clone();
        let mut acc = pool.acc.clone();
        let mut nb = NeuralBlock::new(&layout, true);
        let mut acts = Vec::new();
        let head = nb.forward(&w, &x, &mut acts);
        // head = b_out only
        assert!((head - pool.weights[layout.b_out_off]).abs() < 1e-6);
        let mut rec = GradRecorder::default();
        let mut dinput = vec![0f32; d];
        let mut bufs = Vec::new();
        let n = nb.backward(&mut w, &mut acc, &x, &acts, 1.0, &mut dinput, &mut bufs, &mut rec);
        // only head w_out (all-zero activations are skipped) + b_out
        assert!(n <= 1 + 1, "updates={n}");
        assert!(dinput.iter().all(|&v| v == 0.0));
    }
}
