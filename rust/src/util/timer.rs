//! Timing helpers for benchmarks and perf logging.

use std::time::{Duration, Instant};

/// Scoped stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl std::fmt::Debug for Stopwatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Stopwatch").finish_non_exhaustive()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed().as_secs_f64())
}

/// Measure a closure's median runtime over `reps` repetitions after
/// `warmup` unmeasured runs.  The poor man's criterion used by the
/// `benches/` harness (criterion is unavailable offline).
pub fn median_time<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t = Instant::now();
            std::hint::black_box(f());
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Pretty-print a duration in adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2}s")
    } else if secs < 7200.0 {
        format!("{:.1}m", secs / 60.0)
    } else if secs < 48.0 * 3600.0 {
        format!("{:.1}h", secs / 3600.0)
    } else {
        format!("{:.1}d", secs / 86400.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let s = Stopwatch::new();
        let a = s.elapsed();
        let b = s.elapsed();
        assert!(b >= a);
    }

    #[test]
    fn median_time_positive() {
        let t = median_time(1, 5, || (0..1000).sum::<u64>());
        assert!(t > 0.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("us"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with('s'));
        assert!(fmt_duration(300.0).ends_with('m'));
        assert!(fmt_duration(7200.0).ends_with('h'));
        assert!(fmt_duration(200_000.0).ends_with('d'));
    }
}
