//! Process-wide metrics registry: named counters, gauges, and sharded
//! histograms with a Prometheus-style text render.
//!
//! Design constraints (from the serving hot path):
//!
//! - **Recording never takes a lock.** `Counter`/`Gauge` handles are
//!   cloned `Arc<AtomicU64>`s; histogram recording goes through a
//!   worker-owned [`HistogramShard`] (a lock-free
//!   [`AtomicHistogram`]). The registry's internal mutex is touched
//!   only at registration time and at snapshot/render time.
//! - **Per-worker histogram shards.** Each worker asks the registry
//!   for its own shard of a named histogram; shards are merged only
//!   when a snapshot is taken, so concurrent recorders never contend
//!   on the same cache lines beyond the atomics themselves.
//! - **Names carry labels.** A metric name may embed Prometheus-style
//!   labels (`fw_fleet_link_bytes{class="inter",dc="0"}`); the render
//!   groups samples by base name and emits one `# TYPE` line per base.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::histogram::{AtomicHistogram, LatencyHistogram};

/// Monotonically increasing integer metric. Cloning shares the cell.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        // ordering: Relaxed — a metric counter publishes no other
        // data; scrapes tolerate momentarily stale values.
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // ordering: Relaxed — statistical read, see `add`.
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins float metric (stored as f64 bits). Cloning shares
/// the cell.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        // ordering: Relaxed — last-write-wins metric cell; the store
        // is a single u64 (never torn) and publishes nothing else.
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        // ordering: Relaxed — statistical read, see `set`.
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// One worker's handle on a named histogram: records go straight into
/// the worker's own lock-free shard; the registry merges shards at
/// snapshot time.
#[derive(Clone, Debug)]
pub struct HistogramShard(Arc<AtomicHistogram>);

impl HistogramShard {
    /// Detached shard not registered anywhere — useful for tests and
    /// for probes whose output is read directly.
    pub fn detached() -> Self {
        HistogramShard(Arc::new(AtomicHistogram::new()))
    }

    pub fn record_ns(&self, ns: u64) {
        self.0.record_ns(ns);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.0.record(d);
    }

    pub fn snapshot(&self) -> LatencyHistogram {
        self.0.snapshot()
    }
}

#[derive(Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Vec<Arc<AtomicHistogram>>),
}

#[derive(Debug)]
struct Entry {
    help: String,
    metric: Metric,
}

/// Registry of named metrics. One per serving engine by default (so
/// tests sharing a process don't pollute each other); the `fw` binary
/// threads a single `Arc<ObsRegistry>` through serving, fleet, deploy,
/// and training so one render shows the whole system.
#[derive(Debug, Default)]
pub struct ObsRegistry {
    metrics: Mutex<BTreeMap<String, Entry>>,
}

impl ObsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Process-global registry for binaries that want exactly one.
    pub fn global() -> &'static Arc<ObsRegistry> {
        static GLOBAL: OnceLock<Arc<ObsRegistry>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(ObsRegistry::new()))
    }

    /// Get-or-create a counter. Panics if `name` is already registered
    /// as a different metric kind (programmer error, not runtime state).
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        let mut m = self.metrics.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            metric: Metric::Counter(Counter(Arc::new(AtomicU64::new(0)))),
        });
        match &e.metric {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// Get-or-create a gauge (initialized to 0.0).
    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        let mut m = self.metrics.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            metric: Metric::Gauge(Gauge(Arc::new(AtomicU64::new(0.0f64.to_bits())))),
        });
        match &e.metric {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// Register a fresh shard of a named histogram and hand it to the
    /// caller. Each concurrent recorder should hold its own shard.
    pub fn histogram_shard(&self, name: &str, help: &str) -> HistogramShard {
        let mut m = self.metrics.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            metric: Metric::Histogram(Vec::new()),
        });
        match &mut e.metric {
            Metric::Histogram(shards) => {
                let shard = Arc::new(AtomicHistogram::new());
                shards.push(Arc::clone(&shard));
                HistogramShard(shard)
            }
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// Merged snapshot of a named histogram (all shards folded).
    pub fn histogram_snapshot(&self, name: &str) -> Option<LatencyHistogram> {
        let m = self.metrics.lock().unwrap();
        match &m.get(name)?.metric {
            Metric::Histogram(shards) => {
                let mut merged = LatencyHistogram::new();
                for s in shards {
                    merged.merge(&s.snapshot());
                }
                Some(merged)
            }
            _ => None,
        }
    }

    /// Current value of a named counter, if registered.
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        let m = self.metrics.lock().unwrap();
        match &m.get(name)?.metric {
            Metric::Counter(c) => Some(c.get()),
            _ => None,
        }
    }

    /// Current value of a named gauge, if registered.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        let m = self.metrics.lock().unwrap();
        match &m.get(name)?.metric {
            Metric::Gauge(g) => Some(g.get()),
            _ => None,
        }
    }

    /// Render every metric in Prometheus text exposition format.
    /// Histograms render as `summary` metrics (p50/p90/p99 quantile
    /// samples plus `_sum`/`_count`).
    pub fn render_prometheus(&self) -> String {
        let m = self.metrics.lock().unwrap();
        let mut out = String::new();
        let mut last_base = String::new();
        for (name, e) in m.iter() {
            let base = base_name(name);
            if base != last_base {
                let kind = match &e.metric {
                    Metric::Counter(_) => "counter",
                    Metric::Gauge(_) => "gauge",
                    Metric::Histogram(_) => "summary",
                };
                let _ = writeln!(out, "# HELP {base} {}", e.help);
                let _ = writeln!(out, "# TYPE {base} {kind}");
                last_base = base.to_string();
            }
            match &e.metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "{name} {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "{name} {}", fmt_f64(g.get()));
                }
                Metric::Histogram(shards) => {
                    let mut merged = LatencyHistogram::new();
                    for s in shards {
                        merged.merge(&s.snapshot());
                    }
                    let (base, labels) = split_labels(name);
                    for (q, qs) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
                        let _ = writeln!(
                            out,
                            "{base}{} {}",
                            join_labels(labels, &format!("quantile=\"{qs}\"")),
                            fmt_f64(merged.quantile_ns(q))
                        );
                    }
                    let lbl = labels.map(|l| format!("{{{l}}}")).unwrap_or_default();
                    let _ = writeln!(out, "{base}_sum{lbl} {}", merged.sum_ns());
                    let _ = writeln!(out, "{base}_count{lbl} {}", merged.count());
                }
            }
        }
        out
    }
}

/// `name{labels}` → `name`.
fn base_name(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

/// `name{labels}` → (`name`, Some(`labels`)); plain names get None.
fn split_labels(name: &str) -> (&str, Option<&str>) {
    match name.split_once('{') {
        Some((base, rest)) => (base, Some(rest.trim_end_matches('}'))),
        None => (name, None),
    }
}

fn join_labels(existing: Option<&str>, extra: &str) -> String {
    match existing {
        Some(l) if !l.is_empty() => format!("{{{l},{extra}}}"),
        _ => format!("{{{extra}}}"),
    }
}

/// Prometheus-compatible float formatting (integral values print
/// without a trailing `.0`, which `{}` already does for f64).
fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        (if v > 0.0 { "+Inf" } else { "-Inf" }).to_string()
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop;
    use std::thread;

    #[test]
    fn counter_gauge_roundtrip() {
        let reg = ObsRegistry::new();
        let c = reg.counter("fw_test_total", "test counter");
        c.inc();
        c.add(4);
        assert_eq!(reg.counter_value("fw_test_total"), Some(5));
        // get-or-create returns the same cell
        reg.counter("fw_test_total", "test counter").add(1);
        assert_eq!(c.get(), 6);
        let g = reg.gauge("fw_test_gauge", "test gauge");
        g.set(2.5);
        assert_eq!(reg.gauge_value("fw_test_gauge"), Some(2.5));
    }

    #[test]
    fn histogram_shards_merge_at_snapshot() {
        let reg = ObsRegistry::new();
        let a = reg.histogram_shard("fw_test_ns", "test histogram");
        let b = reg.histogram_shard("fw_test_ns", "test histogram");
        for _ in 0..10 {
            a.record_ns(1_000);
            b.record_ns(100_000);
        }
        let snap = reg.histogram_snapshot("fw_test_ns").unwrap();
        assert_eq!(snap.count(), 20);
        assert_eq!(snap.min_ns(), 1_000);
        assert_eq!(snap.max_ns(), 100_000);
    }

    #[test]
    fn concurrent_recording_totals_exact() {
        // Satellite: N threads hammer counters and histogram shards;
        // after joining, counter totals and the merged histogram count
        // must be exact (no lost updates, no double counts).
        prop(5, |g| {
            let reg = Arc::new(ObsRegistry::new());
            let threads = g.usize_in(2..6);
            let per = g.usize_in(500..4_000) as u64;
            let c = reg.counter("fw_prop_total", "prop counter");
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let reg = Arc::clone(&reg);
                    let c = c.clone();
                    thread::spawn(move || {
                        let shard = reg.histogram_shard("fw_prop_ns", "prop histogram");
                        for i in 0..per {
                            c.inc();
                            shard.record_ns(t as u64 * 7 + i % 1_003 + 1);
                        }
                    })
                })
                .collect();
            for j in handles {
                j.join().unwrap();
            }
            let expect = threads as u64 * per;
            assert_eq!(reg.counter_value("fw_prop_total"), Some(expect));
            let snap = reg.histogram_snapshot("fw_prop_ns").unwrap();
            assert_eq!(snap.count(), expect);
        });
    }

    #[test]
    fn render_groups_labeled_samples_under_one_type_line() {
        let reg = ObsRegistry::new();
        reg.gauge("fw_link_bytes{class=\"inter\",dc=\"0\"}", "per-link bytes")
            .set(100.0);
        reg.gauge("fw_link_bytes{class=\"inter\",dc=\"1\"}", "per-link bytes")
            .set(200.0);
        reg.counter("fw_requests_total", "requests").add(3);
        let shard = reg.histogram_shard("fw_stage_ns", "stage latency");
        shard.record_ns(5_000);
        let text = reg.render_prometheus();
        assert_eq!(text.matches("# TYPE fw_link_bytes gauge").count(), 1);
        assert!(text.contains("fw_link_bytes{class=\"inter\",dc=\"0\"} 100"));
        assert!(text.contains("fw_link_bytes{class=\"inter\",dc=\"1\"} 200"));
        assert!(text.contains("# TYPE fw_requests_total counter"));
        assert!(text.contains("fw_requests_total 3"));
        assert!(text.contains("# TYPE fw_stage_ns summary"));
        assert!(text.contains("fw_stage_ns{quantile=\"0.99\"}"));
        assert!(text.contains("fw_stage_ns_sum 5000"));
        assert!(text.contains("fw_stage_ns_count 1"));
        crate::testutil::check_prometheus_text(&text).expect("render is well-formed");
    }
}
