//! Unified observability plane: metrics registry, per-stage serving
//! spans, and sampled request tracing.
//!
//! Three layers, all zero-dependency:
//!
//! - [`registry`] — process-wide named `Counter`/`Gauge`/`Histogram`
//!   handles backed by relaxed atomics; histograms are per-worker
//!   shards merged at snapshot, so the serving hot path records in
//!   nanoseconds and never takes a lock. Rendered as Prometheus text
//!   exposition via [`ObsRegistry::render_prometheus`].
//! - [`span`] — the [`SpanClock`] each request carries from submit to
//!   reply, stamped per pipeline stage (queue-wait, flush, group
//!   assembly, cache, kernel, total), feeding per-stage histograms.
//! - [`trace`] — a 1-in-N [`RequestTracer`] emitting one JSONL event
//!   per stage for sampled requests plus discrete events (overload
//!   transitions, fleet catch-ups/resyncs, deploy swaps).
//!
//! With no registry attached and sampling off, the serving path is
//! bit-identical to the un-instrumented engine (pinned by test).

pub mod registry;
pub mod span;
pub mod trace;

pub use registry::{Counter, Gauge, HistogramShard, ObsRegistry};
pub use span::{SpanClock, SpanTimes, Stage};
pub use trace::{RequestTracer, TraceSink};

use std::sync::Arc;

/// Observability wiring handed to a subsystem at construction time.
///
/// `Default` means "self-contained": the subsystem creates its own
/// private registry (cheap, and keeps process-shared state out of
/// tests) and no tracer. Binaries that want one unified render pass
/// the same `Arc<ObsRegistry>` (and optionally one tracer) everywhere.
#[derive(Clone, Debug, Default)]
pub struct ObsOptions {
    /// Registry to record into; `None` → a fresh private registry.
    pub registry: Option<Arc<ObsRegistry>>,
    /// Sampled request tracer + discrete-event sink; `None` → no
    /// tracing (and zero per-request sampling cost).
    pub tracer: Option<RequestTracer>,
}

impl ObsOptions {
    pub fn with_registry(registry: Arc<ObsRegistry>) -> Self {
        ObsOptions {
            registry: Some(registry),
            tracer: None,
        }
    }

    pub fn tracer(mut self, tracer: RequestTracer) -> Self {
        self.tracer = Some(tracer);
        self
    }
}
