//! Minimal fleet walkthrough: publish a live Hogwild-trained model to
//! 3 data centers × 2 replicas over lossy simulated links, watch the
//! catch-up protocol heal dropped updates, and compare the planner's
//! star vs fan-out-tree inter-DC byte bills.
//!
//!     cargo run --release --example fleet_fanout

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::fleet::{FleetConfig, FleetFabric, LinkSpec, Strategy, Topology};
use fwumious::model::regressor::Regressor;
use fwumious::train::hogwild::{train_chunk, HogwildConfig};
use fwumious::transfer::UpdateMode;

fn main() {
    let spec = DatasetSpec::tiny();
    let model = ModelConfig::deep_ffm(spec.fields(), 2, 1 << 14, &[8]);
    let mut trainer = Regressor::new(&model);
    let mut stream = SyntheticStream::with_buckets(spec, 7, model.buckets);

    // 5% of inter-DC shipments are lost: replicas fall behind and the
    // fabric heals them (chained-patch replay or full resync)
    let topo = Topology::uniform(
        3,
        2,
        LinkSpec::wan().with_loss(0.05),
        LinkSpec::lan(),
    );
    let mut cfg = FleetConfig::new(topo, UpdateMode::QuantPatch);
    cfg.strategy = Strategy::Auto;
    let mut fabric = FleetFabric::new(cfg, &trainer);

    println!("publishing 8 rounds to 3 DCs x 2 replicas (quant+patch, tree routes):");
    for _ in 0..8 {
        let chunk = stream.take_examples(5_000);
        train_chunk(&mut trainer, &chunk, HogwildConfig { threads: 2 }, 1_000);
        let o = fabric.publish(&trainer).expect("publish");
        println!(
            "  seq {:>2}: {:>7} B on the wire, {} delivered / {} dropped, skew {}",
            o.seq, o.update_bytes, o.delivered, o.dropped, o.max_skew
        );
    }
    let fixed = fabric.converge().expect("converge");
    let m = fabric.metrics();
    println!(
        "\nconverged at seq {} ({} straggler(s) caught up): {} replays, {} resyncs",
        fabric.head(),
        fixed,
        m.replays,
        m.resyncs
    );
    let reference = fabric.reference().expect("published").pool.weights.clone();
    for rep in fabric.replicas() {
        assert_eq!(rep.model().pool.weights, reference);
    }
    println!("all 6 replicas serve bit-identical weights");
    println!(
        "bandwidth bill: {:.2} MB inter-DC + {:.2} MB intra-DC ({} drops billed)",
        m.inter_bytes() as f64 / 1e6,
        m.intra_bytes() as f64 / 1e6,
        m.drops()
    );
    println!(
        "star routing would have crossed the WAN {}x per round instead of {}x",
        fabric.topology().total_replicas(),
        fabric.topology().dcs.len()
    );
}
