//! The serving engine: a thread pool of scoring workers fed through
//! context-affinity shards, with dynamic batching, per-worker context
//! caches, hot model swapping, latency metrics, and an overload plane
//! (admission control, deadline-aware flushing, degraded-mode slates —
//! see [`crate::serve::overload`]).
//!
//! Python is nowhere near this path: workers score through the native
//! Rust forward pass (SIMD-dispatched) against `Arc`-snapshotted weight
//! pools.  The same engine can host a PJRT-backed model through the
//! feature-gated `runtime` module for cross-validation deployments.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{Architecture, ServeConfig, ShedPolicy};
use crate::model::Workspace;
use crate::obs::span::{SpanClock, Stage};
use crate::obs::{Counter, Gauge, HistogramShard, ObsOptions, ObsRegistry, RequestTracer};
use crate::serve::batcher::{context_groups, ContextGroup, DynamicBatcher, FlushReason};
use crate::serve::context_cache::ContextCache;
use crate::serve::overload::{
    BoundedQueue, DegradeLevel, OverloadController, Pop, Push,
};
use crate::serve::router::Router;
use crate::serve::{Request, Response, ServeError, ShedReason};
use crate::util::histogram::LatencyHistogram;
use crate::util::json::{num, obj, s};

/// Aggregated serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub candidates: u64,
    pub batches: u64,
    /// Context groups scored (each is one context-partial lookup and at
    /// most ⌈candidates / max_group_candidates⌉ kernel passes).
    pub groups: u64,
    /// Requests that shared their context group with at least one
    /// other request of the same flushed batch (cross-request
    /// coalescing wins; `requests - groups` over-counts error cases).
    pub coalesced_requests: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Live context-cache entries summed across workers (as of each
    /// worker's last scored batch).
    pub cache_entries: u64,
    pub errors: u64,
    /// Requests rejected at submit (`reject-new` against a full queue).
    pub shed_rejected: u64,
    /// Admitted requests later evicted by a newer one (`drop-oldest`).
    pub shed_dropped: u64,
    /// Admitted requests whose SLO budget ran out before scoring; they
    /// were answered with a deadline error instead of burning kernel
    /// time (their waits feed the overload controller but NOT the
    /// served-latency histogram).
    pub deadline_expired: u64,
    /// Degradation-ladder transitions (both directions, all workers).
    pub degraded_transitions: u64,
    /// Current degradation rung, worst across workers (gauge:
    /// 0 = full, 1 = truncate, 2 = ffm, 3 = lr).
    pub degrade_level: u64,
    /// Jobs sitting in worker queues right now.  Read at the same
    /// single boundary as every other field of a [`ServingEngine::stats`]
    /// snapshot (see its consistency contract); exact once traffic has
    /// quiesced, approximate while submitters are racing the snapshot.
    pub queue_depth: u64,
    /// Latency of requests that reached scoring (shed/expired excluded).
    pub latency: Option<LatencyHistogram>,
}

impl ServeStats {
    pub fn cache_hit_rate(&self) -> f64 {
        let t = self.cache_hits + self.cache_misses;
        if t == 0 {
            0.0
        } else {
            self.cache_hits as f64 / t as f64
        }
    }

    /// Total sheds, both reasons.
    pub fn shed(&self) -> u64 {
        self.shed_rejected + self.shed_dropped
    }

    /// Human label of the [`degrade_level`](Self::degrade_level) gauge.
    pub fn degrade_label(&self) -> &'static str {
        DegradeLevel::LADDER
            .get(self.degrade_level as usize)
            .copied()
            .unwrap_or(DegradeLevel::Full)
            .label()
    }
}

struct Job {
    req: Request,
    /// Span clock started at submit (its `submitted` instant doubles as
    /// the enqueue stamp for deadlines and overload accounting).
    clock: SpanClock,
    /// SLO expiry stamped at admission (None when the SLO is disabled).
    deadline: Option<Instant>,
    reply: SyncSender<Result<Response, ServeError>>,
    /// Trace id when this request was 1-in-N sampled at submit.
    trace: Option<u64>,
}

/// Per-request batcher tag: everything the scorer needs to answer and
/// account for a request after its `Request` was consumed.
struct JobTag {
    clock: SpanClock,
    deadline: Option<Instant>,
    reply: SyncSender<Result<Response, ServeError>>,
    trace: Option<u64>,
}

struct WorkerShared {
    stats: ServeStats,
}

/// Registry counter/gauge handles shared by the client and every
/// worker — recording is a relaxed atomic add, never a lock.
#[derive(Clone)]
struct EngineObs {
    requests: Counter,
    candidates: Counter,
    batches: Counter,
    groups: Counter,
    coalesced: Counter,
    errors: Counter,
    expired: Counter,
    shed_rejected: Counter,
    shed_dropped: Counter,
    transitions: Counter,
    flush_full: Counter,
    flush_deadline: Counter,
    flush_drain: Counter,
    queue_depth: Gauge,
    isa_level: Gauge,
}

impl EngineObs {
    fn new(reg: &ObsRegistry) -> Self {
        let obs = EngineObs {
            requests: reg.counter("fw_serve_requests_total", "requests scored or expired"),
            candidates: reg.counter("fw_serve_candidates_total", "candidates scored"),
            batches: reg.counter("fw_serve_batches_total", "batches flushed to scoring"),
            groups: reg.counter("fw_serve_groups_total", "context groups planned"),
            coalesced: reg.counter(
                "fw_serve_coalesced_requests_total",
                "requests that shared a context group",
            ),
            errors: reg.counter("fw_serve_errors_total", "per-request scoring errors"),
            expired: reg.counter(
                "fw_serve_deadline_expired_total",
                "requests fast-failed past their SLO deadline",
            ),
            shed_rejected: reg.counter(
                "fw_serve_shed_rejected_total",
                "requests rejected at submit (reject-new)",
            ),
            shed_dropped: reg.counter(
                "fw_serve_shed_dropped_total",
                "admitted requests evicted by newer ones (drop-oldest)",
            ),
            transitions: reg.counter(
                "fw_serve_degrade_transitions_total",
                "degradation-ladder transitions, both directions",
            ),
            flush_full: reg.counter(
                "fw_serve_batch_flush_total{reason=\"full\"}",
                "batch flushes by reason",
            ),
            flush_deadline: reg.counter(
                "fw_serve_batch_flush_total{reason=\"deadline\"}",
                "batch flushes by reason",
            ),
            flush_drain: reg.counter(
                "fw_serve_batch_flush_total{reason=\"drain\"}",
                "batch flushes by reason",
            ),
            queue_depth: reg.gauge(
                "fw_serve_queue_depth",
                "jobs in worker queues at the last stats() boundary",
            ),
            isa_level: reg.gauge(
                "fw_isa_level",
                "SIMD ISA rung in use (0=scalar, 1=avx2+fma, 2=avx512)",
            ),
        };
        // Scrapes show which rung this replica actually dispatches —
        // a forced-down or feature-poor host is visible fleet-wide.
        obs.isa_level.set(crate::simd::isa_level() as u8 as f64);
        obs
    }
}

/// Per-worker observability state: one shard of each per-stage
/// histogram (merged only at snapshot — workers never contend) plus
/// the worker-labeled gauges and the sampled tracer.
struct WorkerObs {
    stage_queue: HistogramShard,
    stage_flush: HistogramShard,
    stage_group: HistogramShard,
    stage_cache: HistogramShard,
    stage_kernel: HistogramShard,
    stage_total: HistogramShard,
    /// Every wait the overload controller observes (served + expired) —
    /// the registry view of the controller's windowed-p99 input signal.
    overload_wait: HistogramShard,
    overload_p99: Gauge,
    degrade_level: Gauge,
    cache_entries: Gauge,
    tracer: Option<RequestTracer>,
    worker: usize,
}

impl WorkerObs {
    fn new(reg: &ObsRegistry, worker: usize, tracer: Option<RequestTracer>) -> Self {
        let stage = |st: Stage| {
            reg.histogram_shard(st.metric_name(), "per-stage serving latency (ns)")
        };
        WorkerObs {
            stage_queue: stage(Stage::Queue),
            stage_flush: stage(Stage::Flush),
            stage_group: stage(Stage::Group),
            stage_cache: stage(Stage::Cache),
            stage_kernel: stage(Stage::Kernel),
            stage_total: stage(Stage::Total),
            overload_wait: reg.histogram_shard(
                "fw_serve_overload_wait_ns",
                "waits feeding the overload controller (served + expired)",
            ),
            overload_p99: reg.gauge(
                &format!("fw_serve_overload_p99_ns{{worker=\"{worker}\"}}"),
                "windowed p99 driving the degrade ladder",
            ),
            degrade_level: reg.gauge(
                &format!("fw_serve_degrade_level{{worker=\"{worker}\"}}"),
                "current degrade rung (0=full 1=truncate 2=ffm 3=lr)",
            ),
            cache_entries: reg.gauge(
                &format!("fw_serve_cache_entries{{worker=\"{worker}\"}}"),
                "live context-cache entries",
            ),
            tracer,
            worker,
        }
    }
}

/// Clonable request-submission handle onto a running engine.
///
/// The deployment plane's traffic drivers run on their own threads;
/// each owns a `ServeClient` clone.  Clones may outlive
/// [`ServingEngine::shutdown`]: the worker queues are closed on
/// shutdown, so any submit through a leftover clone fails with
/// [`ServeError::ShutDown`] instead of hanging.
#[derive(Clone)]
pub struct ServeClient {
    router: Router,
    queues: Vec<Arc<BoundedQueue<Job>>>,
    stop: Arc<AtomicBool>,
    shed_policy: ShedPolicy,
    /// SLO budget stamped onto each job (None disables deadlines).
    slo: Option<Duration>,
    obs: EngineObs,
    tracer: Option<RequestTracer>,
}

impl std::fmt::Debug for ServeClient {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServeClient").finish_non_exhaustive()
    }
}

impl ServeClient {
    /// Submit a request; returns the reply channel.
    ///
    /// Never blocks on a saturated engine: a full worker queue sheds
    /// per the configured [`ShedPolicy`] — either this request bounces
    /// with [`ServeError::Shed`] (`reject-new`) or the queue's oldest
    /// waiter is evicted to make room and ITS reply channel gets the
    /// shed error (`drop-oldest`).
    pub fn submit(
        &self,
        req: Request,
    ) -> Result<Receiver<Result<Response, ServeError>>, ServeError> {
        // ordering: Acquire pairs with the Release store in `shutdown`
        // — a submitter that observes the stop flag also observes the
        // queue closes that preceded it, so it fails fast instead of
        // pushing into a queue no worker will ever drain.
        if self.stop.load(Ordering::Acquire) {
            return Err(ServeError::ShutDown);
        }
        // Context-affinity dispatch: the engine derives the router's
        // shard count from the worker count, so `shard_for` IS the
        // worker index — no second modulo re-scrambling the pinned
        // context→shard assignment.
        debug_assert_eq!(self.router.shards, self.queues.len());
        let shard = self.router.shard_for(&req);
        let now = Instant::now();
        let (reply, rx) = sync_channel(1);
        let job = Job {
            req,
            clock: SpanClock::start_at(now),
            deadline: self.slo.map(|d| now + d),
            reply,
            trace: self.tracer.as_ref().and_then(|t| t.try_sample()),
        };
        match self.queues[shard].push(job, self.shed_policy) {
            Push::Admitted => Ok(rx),
            Push::AdmittedDroppingOldest(evicted) => {
                self.obs.shed_dropped.inc();
                let _ = evicted
                    .reply
                    .send(Err(ServeError::Shed(ShedReason::DroppedOldest)));
                Ok(rx)
            }
            Push::Rejected(_) => {
                self.obs.shed_rejected.inc();
                Err(ServeError::Shed(ShedReason::QueueFull))
            }
            Push::Closed(_) => Err(ServeError::ShutDown),
        }
    }

    /// Score a request synchronously.
    pub fn score(&self, req: Request) -> Result<Response, ServeError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| ServeError::ShutDown)?
    }

    /// Jobs sitting in worker queues right now (sum across shards).
    pub fn queue_depth(&self) -> u64 {
        self.queues.iter().map(|q| q.len() as u64).sum()
    }
}

/// The serving engine.
pub struct ServingEngine {
    pub router: Router,
    cfg: ServeConfig,
    client: ServeClient,
    workers: Vec<JoinHandle<()>>,
    shared: Vec<Arc<Mutex<WorkerShared>>>,
    /// Bumped by [`invalidate_caches`](Self::invalidate_caches); workers
    /// clear their context caches when they observe a new epoch.
    cache_epoch: Arc<AtomicU64>,
    /// Metrics registry every counter/gauge/histogram of this engine
    /// lives in (private per engine unless one was passed in through
    /// [`ObsOptions::with_registry`]).
    registry: Arc<ObsRegistry>,
}

impl std::fmt::Debug for ServingEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServingEngine").finish_non_exhaustive()
    }
}

impl ServingEngine {
    /// Spawn `cfg.workers` scoring threads.
    ///
    /// The engine re-derives the router's shard count from the worker
    /// count ([`Router::with_shards`]): a mismatched shard count would
    /// need a second modulo at dispatch, silently re-scrambling the
    /// pinned context→shard affinity that keeps repeated contexts on
    /// one worker's cache.
    pub fn start(router: Router, cfg: ServeConfig) -> Self {
        Self::start_with_obs(router, cfg, ObsOptions::default())
    }

    /// [`start`](Self::start) with an explicit observability
    /// configuration: a shared [`ObsRegistry`] (so one scrape covers
    /// serving + fleet + deploy + train) and/or a sampled
    /// [`RequestTracer`].  The default options give the engine a fresh
    /// private registry and no tracer — recording still happens (it is
    /// nanoseconds of relaxed atomics), but nothing is rendered unless
    /// someone asks.
    pub fn start_with_obs(router: Router, cfg: ServeConfig, obs: ObsOptions) -> Self {
        let workers_n = cfg.workers.max(1);
        let router = router.with_shards(workers_n);
        let cache_epoch = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let registry =
            obs.registry.clone().unwrap_or_else(|| Arc::new(ObsRegistry::new()));
        let tracer = obs.tracer.clone();
        let eobs = EngineObs::new(&registry);
        let mut queues = Vec::new();
        let mut workers = Vec::new();
        let mut shared = Vec::new();
        for w in 0..workers_n {
            let queue = Arc::new(BoundedQueue::new(cfg.queue_depth.max(1)));
            let sh = Arc::new(Mutex::new(WorkerShared {
                stats: ServeStats { latency: Some(LatencyHistogram::new()), ..Default::default() },
            }));
            let router = router.clone();
            let cfg = cfg.clone();
            let sh2 = sh.clone();
            let epoch = cache_epoch.clone();
            let q2 = queue.clone();
            let eobs2 = eobs.clone();
            let wobs = WorkerObs::new(&registry, w, tracer.clone());
            let handle = std::thread::Builder::new()
                .name(format!("fw-serve-{w}"))
                .spawn(move || worker_loop(q2, router, cfg, sh2, epoch, eobs2, wobs))
                .unwrap_or_else(|e| {
                    // an engine with fewer workers than queues would
                    // strand shards; refuse to start half-built
                    panic!("cannot spawn serving worker {w}: {e}")
                });
            queues.push(queue);
            workers.push(handle);
            shared.push(sh);
        }
        let client = ServeClient {
            router: router.clone(),
            queues,
            stop,
            shed_policy: cfg.shed_policy,
            slo: (cfg.request_slo_us > 0)
                .then(|| Duration::from_micros(cfg.request_slo_us)),
            obs: eobs,
            tracer,
        };
        ServingEngine { router, cfg, client, workers, shared, cache_epoch, registry }
    }

    /// The registry this engine records into (render it with
    /// [`ObsRegistry::render_prometheus`]).
    pub fn obs_registry(&self) -> &Arc<ObsRegistry> {
        &self.registry
    }

    /// Score a request synchronously.
    pub fn score(&self, req: Request) -> Result<Response, ServeError> {
        self.client.score(req)
    }

    /// Submit a request; returns the reply channel.
    pub fn submit(
        &self,
        req: Request,
    ) -> Result<Receiver<Result<Response, ServeError>>, ServeError> {
        self.client.submit(req)
    }

    /// A clonable submission handle for traffic-driver threads.
    pub fn client(&self) -> ServeClient {
        self.client.clone()
    }

    /// Clear every worker's context cache (the §6 swap hook).
    ///
    /// Correctness never depends on this — cache keys embed the model
    /// version, so partials computed against swapped-out weights are
    /// unreachable the moment [`crate::serve::ModelHandle::swap`] bumps
    /// the version ("stale partials must never be served").  The epoch
    /// bump reclaims their memory immediately: any batch scored after a
    /// submit that follows this call sees the new epoch (queue push /
    /// pop orders the Release bump before the Acquire load).
    pub fn invalidate_caches(&self) {
        // ordering: Release pairs with the Acquire load in
        // `sync_cache_epoch` — a worker that observes the new epoch
        // also observes the swap that preceded it, so the clear always
        // reclaims the stale entries it was issued for.
        self.cache_epoch.fetch_add(1, Ordering::Release);
    }

    /// Aggregate statistics across workers.
    ///
    /// **Consistency contract:** the snapshot is taken at ONE boundary.
    /// Every worker's stats mutex is acquired up front and held until
    /// every field — per-worker counters, the merged latency histogram,
    /// the shed counters, and the point-in-time gauges (`queue_depth`,
    /// `degrade_level`, `cache_entries`) — has been read.  A worker
    /// publishes a batch's outcome under that same mutex, so no batch
    /// can retire between the gauge reads and the counter reads: the
    /// snapshot is internally consistent (e.g. `groups <= requests`
    /// always holds).  The one residual race is with *submitters*:
    /// queue pushes don't take worker mutexes, so `queue_depth` and the
    /// shed counters are exact only once traffic has quiesced.
    pub fn stats(&self) -> ServeStats {
        // Acquire ALL worker guards first — one cut across the engine.
        // Workers only ever lock their own mutex (no nesting), so grab
        // order cannot deadlock.
        // Poison recovery: worker stats are plain counters updated
        // under the guard; a panicked worker leaves them merely
        // truncated, not torn, and the engine's final stats call (from
        // `shutdown`) must still report what the healthy workers did.
        let guards: Vec<_> = self
            .shared
            .iter()
            .map(|sh| sh.lock().unwrap_or_else(|e| e.into_inner()))
            .collect();
        let mut out = ServeStats { latency: Some(LatencyHistogram::new()), ..Default::default() };
        // Gauges and shed counters read while every worker is paused.
        out.shed_rejected = self.client.obs.shed_rejected.get();
        out.shed_dropped = self.client.obs.shed_dropped.get();
        out.queue_depth = self.client.queue_depth();
        for s in &guards {
            out.requests += s.stats.requests;
            out.candidates += s.stats.candidates;
            out.batches += s.stats.batches;
            out.groups += s.stats.groups;
            out.coalesced_requests += s.stats.coalesced_requests;
            out.cache_hits += s.stats.cache_hits;
            out.cache_misses += s.stats.cache_misses;
            out.cache_entries += s.stats.cache_entries;
            out.errors += s.stats.errors;
            out.deadline_expired += s.stats.deadline_expired;
            out.degraded_transitions += s.stats.degraded_transitions;
            out.degrade_level = out.degrade_level.max(s.stats.degrade_level);
            if let (Some(a), Some(b)) = (out.latency.as_mut(), s.stats.latency.as_ref()) {
                a.merge(b);
            }
        }
        drop(guards);
        self.client.obs.queue_depth.set(out.queue_depth as f64);
        out
    }

    /// Per-worker statistics snapshots, indexed by worker/shard id
    /// (affinity observability: which worker served which context).
    pub fn worker_stats(&self) -> Vec<ServeStats> {
        // poison recovery: see `stats`
        self.shared
            .iter()
            .map(|sh| sh.lock().unwrap_or_else(|e| e.into_inner()).stats.clone())
            .collect()
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Drain queues, join workers, then report final statistics.
    ///
    /// Prompt regardless of linger configuration: closing the worker
    /// queues wakes every parked worker immediately (no riding out the
    /// full `max_wait` linger), yet closed queues still hand out
    /// whatever was admitted before the close, so accepted work is
    /// drained, never dropped.  Leaked [`ServeClient`] clones can't
    /// hold the engine open — their submits bounce off the closed
    /// queues with [`ServeError::ShutDown`].
    pub fn shutdown(mut self) -> ServeStats {
        // ordering: Release pairs with the Acquire in `submit` (see
        // there); the queue closes below are ordered before the flag
        // for threads that synchronize through it.
        self.client.stop.store(true, Ordering::Release);
        for q in &self.client.queues {
            q.close();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.stats()
    }
}

/// Clear the worker's cache when the engine's epoch moved (model swap).
fn sync_cache_epoch(epoch: &AtomicU64, seen: &mut u64, cache: &mut ContextCache) {
    // ordering: Acquire pairs with the Release fetch_add in
    // `invalidate_caches` (see there).
    let e = epoch.load(Ordering::Acquire);
    if e != *seen {
        *seen = e;
        cache.clear();
    }
}

fn worker_loop(
    queue: Arc<BoundedQueue<Job>>,
    router: Router,
    cfg: ServeConfig,
    shared: Arc<Mutex<WorkerShared>>,
    epoch: Arc<AtomicU64>,
    eobs: EngineObs,
    wobs: WorkerObs,
) {
    let mut batcher: DynamicBatcher<JobTag> =
        DynamicBatcher::new(cfg.max_batch, Duration::from_micros(cfg.max_wait_us));
    let mut cache = ContextCache::new(cfg.context_cache_entries);
    // ordering: Acquire seeds the worker's epoch view; pairs with the
    // Release in `invalidate_caches` like `sync_cache_epoch`.
    let mut seen_epoch = epoch.load(Ordering::Acquire);
    let mut ws = Workspace::new();
    let mut ctl = OverloadController::from_slo_us(cfg.request_slo_us);
    loop {
        let wait = batcher
            .time_until_deadline()
            .unwrap_or(Duration::from_millis(50));
        match queue.pop_timeout(wait) {
            Pop::Item(job) => {
                let mut clock = job.clock;
                clock.stamp(Stage::Queue);
                let tag = JobTag {
                    clock,
                    deadline: job.deadline,
                    reply: job.reply,
                    trace: job.trace,
                };
                if let Some(batch) = batcher.push(job.req, tag) {
                    sync_cache_epoch(&epoch, &mut seen_epoch, &mut cache);
                    score_batch(
                        batch, &router, &cfg, &mut cache, &mut ws, &mut ctl, &shared,
                        &eobs, &wobs,
                    );
                }
            }
            Pop::TimedOut => {}
            Pop::Closed => {
                // shutdown: the close already drained the queue into us
                // (Pop::Closed only fires on closed AND empty) — flush
                // what's still lingering in the batcher and exit
                if let Some(batch) = batcher.drain() {
                    sync_cache_epoch(&epoch, &mut seen_epoch, &mut cache);
                    score_batch(
                        batch, &router, &cfg, &mut cache, &mut ws, &mut ctl, &shared,
                        &eobs, &wobs,
                    );
                }
                if let Some(tr) = wobs.tracer.as_ref() {
                    tr.flush();
                }
                return;
            }
        }
        if let Some(batch) = batcher.poll_deadline() {
            sync_cache_epoch(&epoch, &mut seen_epoch, &mut cache);
            score_batch(
                batch, &router, &cfg, &mut cache, &mut ws, &mut ctl, &shared, &eobs,
                &wobs,
            );
        }
    }
}

/// Outcome counters of one coalesced scoring pass (observability).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CoalescePlan {
    /// Context groups planned over the slate.
    pub groups: u64,
    /// Requests that shared their group with at least one other.
    pub coalesced_requests: u64,
}

/// Score a flushed slate of requests with cross-request coalescing —
/// the flushed batch, not the request, is the unit of kernel work.
///
/// Requests are grouped by (model, context) via
/// [`crate::serve::batcher::context_groups`]; each group resolves its
/// model ONCE (one atomic (version, model) read — pairing version N
/// with model N+1 across a concurrent swap would mix stale cached
/// partials into fresh-model responses, see
/// [`crate::serve::ModelHandle`] docs), takes ONE context-cache
/// lookup/insert, and scores every member's candidates as one union
/// slate through `predict_batch_with_partial_capped` (chunked at
/// `max_group_candidates` so a hot context cannot blow the workspace).
/// Scores scatter back to per-request responses preserving request
/// order.
///
/// Error isolation is per request: a malformed request (bad candidate
/// width) fails alone — its group-mates still score.  Whole-group
/// failures (unknown model, context covering every field) are
/// per-request errors too, just identical ones.
///
/// By the kernels' batch-size-invariance contract the union-slate
/// scores are **bit-identical** to scoring each request through its
/// own `predict_batch_with_partial` call
/// (`prop_grouped_scoring_matches_per_request` pins this).
///
/// Results stream through `emit(request_index, result)` as soon as
/// they exist — validation errors immediately, scores right after
/// their group's kernel pass — so the engine replies to a request the
/// moment its group completes instead of after the whole slate (early
/// groups don't pay the later groups' scoring time in latency).
/// `emit` fires exactly once per request; across groups it follows
/// first-seen group order, within a group request order.
pub fn score_requests_coalesced_with(
    router: &Router,
    cache: &mut ContextCache,
    ws: &mut Workspace,
    max_group_candidates: usize,
    requests: &[Request],
    emit: impl FnMut(usize, Result<Response, ServeError>),
) -> CoalescePlan {
    let groups = context_groups(requests.iter());
    score_groups_with(
        router,
        cache,
        ws,
        max_group_candidates,
        None,
        None,
        requests,
        &groups,
        emit,
    )
}

/// Per-stage timing probe threaded into [`score_groups_with`] by the
/// engine's worker loop: cache-lookup and kernel time per group are
/// recorded into the worker's histogram shards, and the most recent
/// group's split is parked in `last` so the emit closure (which runs
/// while the group is borrowed) can attach it to sampled traces.
/// `None` costs nothing — no `Instant::now()` calls are added.
pub struct StageProbe<'a> {
    pub cache: &'a HistogramShard,
    pub kernel: &'a HistogramShard,
    /// (cache_ns, kernel_ns) of the most recently scored group.
    pub last: std::cell::Cell<(u64, u64)>,
}

impl std::fmt::Debug for StageProbe<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StageProbe").finish_non_exhaustive()
    }
}

/// The group-scoring core behind [`score_requests_coalesced_with`]:
/// takes the context groups PRE-COMPUTED (and possibly re-ordered or
/// member-filtered — the deadline scheduler sorts groups by their
/// oldest member's remaining budget and strips expired members first)
/// plus an optional architecture cap (the degraded-mode ladder rung;
/// `None` serves each model as configured — bit-neutral).
///
/// `emit` fires exactly once per request present in `groups`, in group
/// order, member order within a group.  Requests absent from `groups`
/// are the caller's to answer.
#[allow(clippy::too_many_arguments)]
pub fn score_groups_with(
    router: &Router,
    cache: &mut ContextCache,
    ws: &mut Workspace,
    max_group_candidates: usize,
    arch_cap: Option<Architecture>,
    probe: Option<&StageProbe>,
    requests: &[Request],
    groups: &[ContextGroup],
    mut emit: impl FnMut(usize, Result<Response, ServeError>),
) -> CoalescePlan {
    let mut plan = CoalescePlan::default();
    let mut scores: Vec<f32> = Vec::new();
    for group in groups {
        let Some(&first_idx) = group.members.first() else { continue };
        plan.groups += 1;
        if group.members.len() > 1 {
            plan.coalesced_requests += group.members.len() as u64;
        }
        let first = &requests[first_idx];
        let handle = match router.resolve(&first.model) {
            Some(h) => h,
            None => {
                for &i in &group.members {
                    emit(
                        i,
                        Err(ServeError::Scoring(format!(
                            "unknown model '{}'",
                            first.model
                        ))),
                    );
                }
                continue;
            }
        };
        let (version, model) = handle.load_versioned();
        if first.context.len() >= model.cfg.fields {
            for &i in &group.members {
                emit(
                    i,
                    Err(ServeError::Scoring(
                        "context covers all fields; no candidate slots".into(),
                    )),
                );
            }
            continue;
        }
        let need = model.cfg.fields - first.context.len();
        // Per-request validation: one malformed request must not fail
        // its group-mates (it errors out immediately, alone).
        let mut valid = Vec::with_capacity(group.members.len());
        for &i in &group.members {
            match requests[i].candidates.iter().find(|c| c.len() != need) {
                Some(cand) => emit(
                    i,
                    Err(ServeError::Scoring(format!(
                        "candidate has {} slots, model needs {need}",
                        cand.len(),
                    ))),
                ),
                None => valid.push(i),
            }
        }
        if valid.is_empty() {
            continue;
        }
        // ONE context-partial lookup/insert per group.  The partial is
        // rung-independent, so one cache entry serves every degrade
        // level.
        let t_cache = probe.map(|_| Instant::now());
        let cp =
            cache.get_or_compute_named(&model, &first.model, version, &first.context);
        let cache_ns = t_cache.map(|t| t.elapsed().as_nanos() as u64);
        // Union slate: every valid member's candidates, request order.
        let mut slate: Vec<&[crate::feature::FeatureSlot]> =
            Vec::with_capacity(group.candidates);
        for &i in &valid {
            for cand in &requests[i].candidates {
                slate.push(cand.as_slice());
            }
        }
        let t_kernel = probe.map(|_| Instant::now());
        model.predict_batch_with_partial_capped_as(
            arch_cap.unwrap_or(model.cfg.arch),
            &cp,
            &slate,
            max_group_candidates,
            ws,
            &mut scores,
        );
        if let Some(p) = probe {
            let c_ns = cache_ns.unwrap_or(0);
            let k_ns = t_kernel.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0);
            p.cache.record_ns(c_ns);
            p.kernel.record_ns(k_ns);
            p.last.set((c_ns, k_ns));
        }
        // Scatter back, preserving request order within the group.
        let mut off = 0usize;
        for &i in &valid {
            let n = requests[i].candidates.len();
            emit(i, Ok(Response { scores: scores[off..off + n].to_vec() }));
            off += n;
        }
    }
    plan
}

/// [`score_requests_coalesced_with`] collecting results into a Vec
/// indexed like `requests` (tests, benches, batch-oriented callers).
pub fn score_requests_coalesced(
    router: &Router,
    cache: &mut ContextCache,
    ws: &mut Workspace,
    max_group_candidates: usize,
    requests: &[Request],
) -> (Vec<Result<Response, ServeError>>, CoalescePlan) {
    let mut results: Vec<Option<Result<Response, ServeError>>> = Vec::new();
    results.resize_with(requests.len(), || None);
    let plan = score_requests_coalesced_with(
        router,
        cache,
        ws,
        max_group_candidates,
        requests,
        |i, r| results[i] = Some(r),
    );
    let results = results
        .into_iter()
        .map(|r| {
            // the planner emits every index exactly once; degrade an
            // unplanned slot to a scoring error rather than panicking
            r.unwrap_or_else(|| {
                Err(ServeError::Scoring("request not planned into any group".into()))
            })
        })
        .collect();
    (results, plan)
}

/// Score one flushed batch through the overload plane:
///
/// 1. **Degraded truncation** — while the worker's overload controller
///    sits at [`DegradeLevel::Truncate`] or below, candidate slates are
///    truncated to `degraded_max_candidates` before any kernel work.
/// 2. **Deadline scheduling** — with an SLO configured, context groups
///    are scored oldest-member-first (the group closest to blowing its
///    budget goes first) and members whose deadline already passed are
///    fast-failed with [`ServeError::DeadlineExpired`] instead of
///    burning kernel time.  Expired waits feed the overload controller
///    (a wait that blew the SLO is the strongest overload signal) but
///    NOT the served-latency histogram.
/// 3. **Degraded architecture** — at [`DegradeLevel::Ffm`]/
///    [`DegradeLevel::Lr`] the group scorer drops down the
///    DeepFFM→FFM→LR ladder via the regressor's arch-override path.
///
/// With `request_slo_us == 0` (the default) every step above is
/// disabled and this is bit-identical to the pre-overload engine:
/// first-seen group order, no truncation, models served as configured.
#[allow(clippy::too_many_arguments)]
fn score_batch(
    batch: crate::serve::batcher::Batch<JobTag>,
    router: &Router,
    cfg: &ServeConfig,
    cache: &mut ContextCache,
    ws: &mut Workspace,
    ctl: &mut OverloadController,
    shared: &Arc<Mutex<WorkerShared>>,
    eobs: &EngineObs,
    wobs: &WorkerObs,
) {
    let flush_start = Instant::now();
    match batch.reason {
        FlushReason::Full => eobs.flush_full.inc(),
        FlushReason::Deadline => eobs.flush_deadline.inc(),
        FlushReason::Drain => eobs.flush_drain.inc(),
    }
    let mut candidates = 0u64;
    let mut errors = 0u64;
    let mut expired = 0u64;
    let mut hist = LatencyHistogram::new();
    let (hits0, misses0) = (cache.hits, cache.misses);

    let (mut reqs, mut tags): (Vec<Request>, Vec<JobTag>) =
        batch.items.into_iter().unzip();
    // Flush stage: pop-to-flush (batcher linger), charged per request.
    for t in &mut tags {
        t.clock.stamp_at(Stage::Flush, flush_start);
    }

    let level = ctl.level();
    if level.truncates() {
        let cap = cfg.degraded_max_candidates.max(1);
        for r in &mut reqs {
            r.candidates.truncate(cap);
        }
    }

    let t_group = Instant::now();
    let mut groups = context_groups(reqs.iter());
    if ctl.enabled() {
        // Deadline-aware order: the group whose oldest member has the
        // least remaining budget is scored first.  (Same SLO for every
        // request ⇒ oldest enqueue == smallest remaining budget.)
        groups.sort_by_key(|g| {
            g.members.iter().map(|&i| tags[i].clock.submitted).min()
        });
    }

    let mut tags: Vec<Option<JobTag>> = tags.into_iter().map(Some).collect();

    if ctl.enabled() {
        // Fast-fail members that expired while queued — before any
        // kernel work, so a flood of dead requests costs near zero.
        let now = Instant::now();
        for g in &mut groups {
            g.members.retain(|&i| {
                // A missing tag means the request was already answered
                // — structurally impossible before scoring, but drop it
                // from the group instead of panicking a worker.
                let Some(tag) = tags[i].as_ref() else { return false };
                let keep = tag.deadline.map_or(true, |d| d > now);
                if !keep {
                    let Some(t) = tags[i].take() else { return false };
                    let waited = t.clock.submitted.elapsed();
                    let waited_ns = waited.as_nanos().min(u64::MAX as u128) as u64;
                    ctl.observe_ns(waited_ns);
                    // Expired waits feed the overload-signal histogram
                    // but never the served-latency stage histograms.
                    wobs.overload_wait.record_ns(waited_ns);
                    expired += 1;
                    let _ = t.reply.send(Err(ServeError::DeadlineExpired {
                        waited_us: waited.as_micros().min(u64::MAX as u128) as u64,
                        slo_us: cfg.request_slo_us,
                    }));
                }
                keep
            });
            g.candidates =
                g.members.iter().map(|&i| reqs[i].candidates.len()).sum();
        }
        groups.retain(|g| !g.members.is_empty());
    }

    // Group-assembly stage: grouping + deadline scheduling, charged
    // once per batch into the shard and onto each surviving clock.
    let group_ns = t_group.elapsed().as_nanos().min(u64::MAX as u128) as u64;
    wobs.stage_group.record_ns(group_ns);
    for t in tags.iter_mut().flatten() {
        t.clock.add_ns(Stage::Group, group_ns);
    }

    // Sampled-trace support: group membership sizes, built only when
    // this batch actually carries a sampled request.
    let traced_any =
        wobs.tracer.is_some() && tags.iter().flatten().any(|t| t.trace.is_some());
    let group_of: Option<Vec<u32>> = traced_any.then(|| {
        let mut m = vec![0u32; reqs.len()];
        for g in &groups {
            for &i in &g.members {
                m[i] = g.members.len() as u32;
            }
        }
        m
    });
    let batch_size = reqs.len();

    let probe = StageProbe {
        cache: &wobs.stage_cache,
        kernel: &wobs.stage_kernel,
        last: std::cell::Cell::new((0, 0)),
    };

    // Streamed scatter: each request is answered the moment its group
    // completes, so requests in early groups don't pay the later
    // groups' scoring time in (real or recorded) latency.
    let plan = score_groups_with(
        router,
        cache,
        ws,
        cfg.max_group_candidates,
        level.arch_cap(),
        Some(&probe),
        &reqs,
        &groups,
        |i, result| {
            let n_scores = match &result {
                Ok(resp) => {
                    candidates += resp.scores.len() as u64;
                    resp.scores.len()
                }
                Err(_) => {
                    errors += 1;
                    0
                }
            };
            // the planner emits each request exactly once; a missing
            // tag (already answered) has nobody waiting — skip it
            let Some(mut t) = tags[i].take() else { return };
            let total_ns = t.clock.finish_at(Instant::now());
            hist.record_ns(total_ns);
            ctl.observe_ns(total_ns);
            wobs.overload_wait.record_ns(total_ns);
            wobs.stage_total.record_ns(total_ns);
            wobs.stage_queue.record_ns(t.clock.times.get(Stage::Queue));
            wobs.stage_flush.record_ns(t.clock.times.get(Stage::Flush));
            if let (Some(tracer), Some(id)) = (wobs.tracer.as_ref(), t.trace) {
                // Cache/kernel split of the group just scored (errors
                // emitted before any kernel pass read zeros).
                let (c_ns, k_ns) = probe.last.get();
                t.clock.add_ns(Stage::Cache, c_ns);
                t.clock.add_ns(Stage::Kernel, k_ns);
                let group_key = crate::serve::batcher::group_key_hash(
                    &reqs[i].model,
                    &reqs[i].context,
                );
                for st in Stage::ALL {
                    tracer.emit(&obj(vec![
                        ("event", s("stage")),
                        ("trace", num(id as f64)),
                        ("stage", s(st.label())),
                        ("ns", num(t.clock.times.get(st) as f64)),
                        ("model", s(&reqs[i].model)),
                        ("group_key", s(&format!("{group_key:016x}"))),
                        ("degrade", s(level.label())),
                        ("worker", num(wobs.worker as f64)),
                        ("batch", num(batch_size as f64)),
                        (
                            "group",
                            num(group_of
                                .as_ref()
                                .map(|m| m[i] as f64)
                                .unwrap_or(0.0)),
                        ),
                        ("candidates", num(n_scores as f64)),
                    ]));
                }
            }
            let _ = t.reply.send(result); // receiver may have gone away
        },
    );

    if let Some(new_level) = ctl.decide() {
        eobs.transitions.inc();
        if let Some(tracer) = wobs.tracer.as_ref() {
            tracer.emit(&obj(vec![
                ("event", s("overload_transition")),
                ("worker", num(wobs.worker as f64)),
                ("level", s(new_level.label())),
                ("p99_ns", num(ctl.windowed_p99_ns() as f64)),
            ]));
        }
    }
    wobs.overload_p99.set(ctl.windowed_p99_ns() as f64);
    wobs.degrade_level.set(ctl.level() as u64 as f64);
    wobs.cache_entries.set(cache.entries() as f64);

    eobs.requests.add(reqs.len() as u64);
    eobs.candidates.add(candidates);
    eobs.batches.inc();
    eobs.groups.add(plan.groups);
    eobs.coalesced.add(plan.coalesced_requests);
    eobs.errors.add(errors);
    eobs.expired.add(expired);

    // poison recovery: see `ServingEngine::stats`
    let mut sh = shared.lock().unwrap_or_else(|e| e.into_inner());
    sh.stats.requests += reqs.len() as u64;
    sh.stats.candidates += candidates;
    sh.stats.batches += 1;
    sh.stats.groups += plan.groups;
    sh.stats.coalesced_requests += plan.coalesced_requests;
    sh.stats.errors += errors;
    sh.stats.deadline_expired += expired;
    sh.stats.degraded_transitions = ctl.transitions;
    sh.stats.degrade_level = ctl.level() as u64;
    sh.stats.cache_hits += cache.hits - hits0;
    sh.stats.cache_misses += cache.misses - misses0;
    sh.stats.cache_entries = cache.entries() as u64;
    if let Some(l) = sh.stats.latency.as_mut() {
        l.merge(&hist);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::regressor::Regressor;
    use crate::obs::TraceSink;
    use crate::serve::trace::TraceGenerator;
    use crate::serve::ModelHandle;

    fn engine(workers: usize, cache: usize) -> (ServingEngine, TraceGenerator) {
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg = Regressor::new(&cfg);
        let router = Router::new(workers);
        router.register("ctr", ModelHandle::new(reg));
        let serve_cfg = ServeConfig {
            workers,
            max_batch: 64,
            max_wait_us: 100,
            context_cache_entries: cache,
            ..ServeConfig::default()
        };
        let gen = TraceGenerator::new(7, 6, 3, 1 << 10, 4);
        (ServingEngine::start(router, serve_cfg), gen)
    }

    #[test]
    fn scores_requests_end_to_end() {
        let (eng, mut gen) = engine(2, 1024);
        for _ in 0..200 {
            let req = gen.next_request("ctr");
            let n = req.candidates.len();
            let resp = eng.score(req).unwrap();
            assert_eq!(resp.scores.len(), n);
            assert!(resp.scores.iter().all(|s| (0.0..=1.0).contains(s)));
        }
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 200);
        assert!(stats.candidates >= 200);
        assert!(stats.cache_hits + stats.cache_misses >= 200);
        // the overload plane is disarmed by default
        assert_eq!(stats.shed(), 0);
        assert_eq!(stats.deadline_expired, 0);
        assert_eq!(stats.degraded_transitions, 0);
        assert_eq!(stats.degrade_level, 0);
    }

    #[test]
    fn unknown_model_is_an_error_not_a_crash() {
        let (eng, mut gen) = engine(1, 0);
        let req = gen.next_request("nope");
        assert!(matches!(eng.score(req), Err(ServeError::Scoring(_))));
        let stats = eng.shutdown();
        assert_eq!(stats.errors, 1);
    }

    #[test]
    fn concurrent_submissions() {
        let (eng, mut gen) = engine(4, 1024);
        let reqs: Vec<Request> =
            (0..400).map(|_| gen.next_request("ctr")).collect();
        let rxs: Vec<_> = reqs
            .into_iter()
            .map(|r| {
                let n = r.candidates.len();
                (n, eng.submit(r).unwrap())
            })
            .collect();
        for (n, rx) in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.scores.len(), n);
        }
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 400);
        assert!(stats.latency.unwrap().count() == 400);
    }

    #[test]
    fn hot_swap_serves_new_weights() {
        let cfg = ModelConfig::linear(4, 256);
        let reg0 = Regressor::new(&cfg);
        let router = Router::new(1);
        let handle = ModelHandle::new(reg0);
        router.register("m", handle.clone());
        let eng = ServingEngine::start(
            router,
            ServeConfig {
                workers: 1,
                max_batch: 8,
                max_wait_us: 50,
                context_cache_entries: 64,
                ..ServeConfig::default()
            },
        );
        let mut gen = TraceGenerator::new(9, 4, 2, 256, 2);
        let req = gen.next_request("m");
        let before = eng.score(req.clone()).unwrap();
        // swap in a model with shifted LR weights -> all scores change
        let mut reg1 = Regressor::new(&cfg);
        for w in reg1.pool.weights.iter_mut() {
            *w = 0.5;
        }
        handle.swap(reg1);
        let after = eng.score(req).unwrap();
        assert_ne!(before, after);
        assert!(after.scores.iter().all(|&s| s > 0.6)); // positive weights
        eng.shutdown();
    }

    #[test]
    fn swap_never_serves_stale_partials() {
        // Regression test for the context_cache.rs invariant: after a
        // weight swap the engine must never serve partials computed
        // against the old weights.  Single worker, single repeated
        // context -> the cache is primed and hot before the swap.
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg0 = Regressor::new(&cfg);
        let handle = ModelHandle::new(reg0);
        let router = Router::new(1);
        router.register("m", handle.clone());
        let eng = ServingEngine::start(
            router,
            ServeConfig {
                workers: 1,
                max_batch: 8,
                max_wait_us: 50,
                context_cache_entries: 1024,
                ..ServeConfig::default()
            },
        );
        let mut gen = TraceGenerator::new(17, 6, 3, 1 << 10, 4);
        let mut req = gen.next_request("m");
        // pin a single context so both pre-swap requests share it
        let r2 = gen.next_request("m");
        req.candidates.extend(r2.candidates);
        let before1 = eng.score(req.clone()).unwrap();
        let before2 = eng.score(req.clone()).unwrap();
        assert_eq!(before1, before2); // cache hit served identical scores

        // swap in visibly different weights
        let mut reg1 = Regressor::new(&cfg);
        for w in reg1.pool.weights.iter_mut() {
            *w = 0.25;
        }
        handle.swap(reg1);
        eng.invalidate_caches();

        let after = eng.score(req.clone()).unwrap();
        assert_ne!(before1, after, "stale partials served after swap");
        // scores must equal a fresh computation against the NEW model
        // through the same partial-forward path
        let current = handle.load();
        let mut ws = Workspace::new();
        let cp = current.context_partial(&req.context);
        for (i, cand) in req.candidates.iter().enumerate() {
            let direct = current.predict_with_partial(&cp, cand, &mut ws);
            assert_eq!(after.scores[i], direct, "candidate {i} mismatch");
        }
        let stats = eng.shutdown();
        // 1 miss (prime) + 1 hit (repeat) + 1 miss (post-swap recompute)
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(stats.cache_misses, 2);
        // the epoch clear dropped the pre-swap entry: only the fresh one
        // remains live
        assert_eq!(stats.cache_entries, 1);
    }

    #[test]
    fn client_clones_submit_from_other_threads() {
        let (eng, mut gen) = engine(2, 1024);
        let reqs: Vec<Request> = (0..120).map(|_| gen.next_request("ctr")).collect();
        let mut joins = Vec::new();
        for t in 0..3 {
            let client = eng.client();
            let reqs = reqs.clone();
            joins.push(std::thread::spawn(move || {
                let mut scored = 0usize;
                for (i, req) in reqs.into_iter().enumerate() {
                    if i % 3 == t {
                        let resp = client.score(req).unwrap();
                        scored += resp.scores.len();
                    }
                }
                scored
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert!(total >= 120);
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 120);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn shutdown_does_not_hang_with_leaked_client() {
        let (eng, mut gen) = engine(2, 64);
        let leaked = eng.client();
        eng.score(gen.next_request("ctr")).unwrap();
        // the live clone keeps queue Arcs alive; workers must exit on
        // queue close anyway
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 1);
        // post-shutdown submits through the leftover clone fail cleanly
        assert_eq!(
            leaked.score(gen.next_request("ctr")).unwrap_err(),
            ServeError::ShutDown
        );
    }

    #[test]
    fn shutdown_is_prompt_despite_long_linger() {
        // Regression: workers used to notice the stop flag only on the
        // recv timeout arm, so a pending batch meant shutdown waited
        // out the FULL linger.  With a 5s linger and a queued request,
        // shutdown must still return quickly — and still answer the
        // queued request (drain, not drop).
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(Regressor::new(&cfg)));
        let eng = ServingEngine::start(
            router,
            ServeConfig {
                workers: 1,
                max_batch: 1_000_000, // never flush on Full
                max_wait_us: 5_000_000, // 5s linger
                context_cache_entries: 64,
                ..ServeConfig::default()
            },
        );
        let _leaked = eng.client(); // keep channels open like a driver would
        let mut gen = TraceGenerator::new(7, 6, 3, 1 << 10, 4);
        let rx = eng.submit(gen.next_request("ctr")).unwrap();
        // give the worker a beat to pull the job into its batcher
        std::thread::sleep(Duration::from_millis(30));
        let t0 = Instant::now();
        let stats = eng.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "shutdown rode out the linger: {:?}",
            t0.elapsed()
        );
        assert_eq!(stats.requests, 1, "queued request was dropped");
        assert!(rx.recv().unwrap().is_ok(), "queued request went unanswered");
    }

    #[test]
    fn context_affinity_pins_contexts_to_derived_shards() {
        // Regression: with router.shards != workers the old double
        // modulo re-scrambled shard_for's pinned assignment.  The
        // engine must derive the shard count from the worker count so
        // dispatch IS shard_for_context(ctx, workers).
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let router = Router::new(7); // deliberately wrong shard count
        router.register("ctr", ModelHandle::new(Regressor::new(&cfg)));
        let workers = 4;
        let eng = ServingEngine::start(
            router,
            ServeConfig { workers, max_batch: 8, max_wait_us: 50, ..ServeConfig::default() },
        );
        assert_eq!(eng.router.shards, workers);
        let mut gen = TraceGenerator::new(23, 6, 3, 1 << 10, 4);
        let donor = gen.next_request("ctr");
        let want_shard =
            Router::shard_for_context(&donor.context, workers);
        for _ in 0..24 {
            let mut r = gen.next_request("ctr");
            r.context = donor.context.clone();
            eng.score(r).unwrap();
        }
        let per_worker = eng.worker_stats();
        for (w, s) in per_worker.iter().enumerate() {
            if w == want_shard {
                assert_eq!(s.requests, 24, "affinity shard missed traffic");
            } else {
                assert_eq!(s.requests, 0, "worker {w} stole affine traffic");
            }
        }
        eng.shutdown();
    }

    #[test]
    fn zero_candidate_requests_score_empty_and_coalesce() {
        // An empty slate must come back Ok(scores: []) — alone, and as
        // a member of a shared-context group — and must never flush a
        // batch on its own (it contributes zero candidates).
        let (eng, mut gen) = engine(1, 1024);
        let mut lone = gen.next_request("ctr");
        lone.candidates.clear();
        assert_eq!(eng.score(lone).unwrap().scores, Vec::<f32>::new());

        let donor = gen.next_request("ctr");
        let mut empty = gen.next_request("ctr");
        empty.context = donor.context.clone();
        empty.candidates.clear();
        let rx_full = eng.submit(donor.clone()).unwrap();
        let rx_empty = eng.submit(empty).unwrap();
        assert_eq!(
            rx_full.recv().unwrap().unwrap().scores.len(),
            donor.candidates.len()
        );
        assert_eq!(rx_empty.recv().unwrap().unwrap().scores, Vec::<f32>::new());
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.errors, 0);
    }

    /// Heavy-scoring engine for shed tests: fanout large enough that
    /// one in-flight batch keeps the worker busy while submits flood a
    /// depth-1 queue.
    fn overload_engine(policy: ShedPolicy) -> (ServingEngine, TraceGenerator) {
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[16, 16]);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(Regressor::new(&cfg)));
        let serve_cfg = ServeConfig {
            workers: 1,
            max_batch: 1, // every request flushes (and scores) alone
            max_wait_us: 50,
            context_cache_entries: 0,
            queue_depth: 1,
            shed_policy: policy,
            ..ServeConfig::default()
        };
        let gen = TraceGenerator::new(31, 6, 3, 1 << 10, 256);
        (ServingEngine::start(router, serve_cfg), gen)
    }

    #[test]
    fn reject_new_sheds_at_submit_and_serves_the_rest() {
        let (eng, mut gen) = overload_engine(ShedPolicy::RejectNew);
        let n = 200;
        let mut rxs = Vec::new();
        let mut shed = 0u64;
        for _ in 0..n {
            match eng.submit(gen.next_request("ctr")) {
                Ok(rx) => rxs.push(rx),
                Err(ServeError::Shed(ShedReason::QueueFull)) => shed += 1,
                Err(e) => panic!("unexpected: {e}"),
            }
        }
        // every admitted request is answered with real scores
        for rx in rxs.iter() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.scores.len(), 256);
        }
        let stats = eng.shutdown();
        assert!(shed > 0, "queue_depth=1 under flood must shed");
        assert_eq!(stats.shed_rejected, shed);
        assert_eq!(stats.shed_dropped, 0);
        assert_eq!(stats.requests + shed, n);
        assert_eq!(stats.requests, rxs.len() as u64);
    }

    #[test]
    fn drop_oldest_evicts_queued_requests_not_new_ones() {
        let (eng, mut gen) = overload_engine(ShedPolicy::DropOldest);
        let n = 200;
        // every submit is ADMITTED under drop-oldest...
        let rxs: Vec<_> = (0..n)
            .map(|_| eng.submit(gen.next_request("ctr")).unwrap())
            .collect();
        // ...but some earlier victims got evicted and answered Shed
        let mut served = 0u64;
        let mut dropped = 0u64;
        for rx in rxs {
            match rx.recv().unwrap() {
                Ok(resp) => {
                    assert_eq!(resp.scores.len(), 256);
                    served += 1;
                }
                Err(ServeError::Shed(ShedReason::DroppedOldest)) => dropped += 1,
                Err(e) => panic!("unexpected: {e}"),
            }
        }
        let stats = eng.shutdown();
        assert!(dropped > 0, "depth-1 queue under flood must evict");
        assert_eq!(served + dropped, n);
        assert_eq!(stats.shed_dropped, dropped);
        assert_eq!(stats.shed_rejected, 0);
        assert_eq!(stats.requests, served);
    }

    #[test]
    fn expired_requests_fast_fail_with_deadline_error() {
        // SLO 1us, linger 5ms, Full flush unreachable: every request
        // is guaranteed to expire in the queue and must come back as
        // DeadlineExpired without touching the kernels.
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(Regressor::new(&cfg)));
        let eng = ServingEngine::start(
            router,
            ServeConfig {
                workers: 1,
                max_batch: 1_000_000,
                max_wait_us: 5_000,
                request_slo_us: 1,
                ..ServeConfig::default()
            },
        );
        let mut gen = TraceGenerator::new(37, 6, 3, 1 << 10, 4);
        let rxs: Vec<_> = (0..20)
            .map(|_| eng.submit(gen.next_request("ctr")).unwrap())
            .collect();
        for rx in rxs {
            match rx.recv().unwrap() {
                Err(ServeError::DeadlineExpired { waited_us, slo_us }) => {
                    assert_eq!(slo_us, 1);
                    assert!(waited_us >= 1);
                }
                other => panic!("expected deadline expiry, got {other:?}"),
            }
        }
        let stats = eng.shutdown();
        assert_eq!(stats.deadline_expired, 20);
        assert_eq!(stats.requests, 20);
        assert_eq!(stats.errors, 0, "expiries are not scoring errors");
        // expired requests never reach the served-latency histogram
        assert_eq!(stats.latency.unwrap().count(), 0);
    }

    #[test]
    fn generous_slo_is_bit_neutral_with_deadline_machinery_armed() {
        // With the SLO armed but generous, every request is in-SLO at
        // DegradeLevel::Full: responses must be bitwise what the
        // per-request partial path computes (the overload plane must
        // not perturb admitted, in-SLO traffic).
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg = Regressor::new(&cfg);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(reg.clone()));
        let eng = ServingEngine::start(
            router,
            ServeConfig {
                workers: 1,
                max_batch: 64,
                max_wait_us: 100,
                request_slo_us: 10_000_000, // 10s: nothing expires
                ..ServeConfig::default()
            },
        );
        let mut gen = TraceGenerator::new(41, 6, 3, 1 << 10, 4);
        let mut ws = Workspace::new();
        for _ in 0..50 {
            let req = gen.next_request("ctr");
            let resp = eng.score(req.clone()).unwrap();
            let cp = reg.context_partial(&req.context);
            let mut want = Vec::new();
            reg.predict_batch_with_partial(&cp, &req.candidates, &mut ws, &mut want);
            assert_eq!(resp.scores, want, "armed-but-idle overload plane drifted");
        }
        let stats = eng.shutdown();
        assert_eq!(stats.deadline_expired, 0);
        assert_eq!(stats.degraded_transitions, 0);
        assert_eq!(stats.degrade_level, 0);
    }

    #[test]
    fn coalesced_slate_matches_per_request_and_isolates_errors() {
        // one flushed slate: 3 requests sharing context A (one of them
        // malformed), 1 on context B, 1 for an unknown model.  The
        // malformed request and the unknown model fail ALONE; everyone
        // else scores bitwise what the per-request path produces.
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg = Regressor::new(&cfg);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(reg.clone()));
        let mut gen = TraceGenerator::new(51, 6, 3, 1 << 10, 4);
        let a = gen.next_request("ctr");
        let b = gen.next_request("ctr");
        let mut a2 = gen.next_request("ctr");
        a2.context = a.context.clone();
        let mut bad = gen.next_request("ctr");
        bad.context = a.context.clone();
        let _ = bad.candidates[1].pop(); // wrong width: 2 slots, model needs 3
        let mut alien = gen.next_request("nope");
        alien.context = a.context.clone();
        let reqs = vec![a.clone(), bad.clone(), b.clone(), alien.clone(), a2.clone()];
        let mut cache = ContextCache::new(1024);
        let mut ws = Workspace::new();
        let (results, plan) = score_requests_coalesced(&router, &mut cache, &mut ws, 1024, &reqs);
        assert_eq!(results.len(), 5);
        // groups: A{a, bad, a2}, B{b}, alien (model name splits groups)
        assert_eq!(plan.groups, 3);
        assert_eq!(plan.coalesced_requests, 3);
        assert!(results[1].as_ref().unwrap_err().to_string().contains("2 slots"));
        assert!(results[3]
            .as_ref()
            .unwrap_err()
            .to_string()
            .contains("unknown model"));
        // survivors match the per-request batched path bitwise
        let mut ws_ref = Workspace::new();
        for (i, req) in [(0usize, &a), (2, &b), (4, &a2)] {
            let cp = reg.context_partial(&req.context);
            let mut want = Vec::new();
            reg.predict_batch_with_partial(&cp, &req.candidates, &mut ws_ref, &mut want);
            assert_eq!(
                results[i].as_ref().unwrap().scores,
                want,
                "request {i} diverged from the per-request path"
            );
        }
        // ONE cache lookup per group that reached scoring: A and B
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.hits, 0);
        // a second identical slate hits both cached partials
        let (_, plan2) = score_requests_coalesced(&router, &mut cache, &mut ws, 1024, &reqs);
        assert_eq!(plan2, plan);
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.hits, 2);
    }

    #[test]
    fn engine_coalesces_same_context_submissions() {
        // Same-context requests submitted together route to one shard
        // (context-affinity) and — whenever the batcher flushes them in
        // one batch — score as one group.  Responses must be correct
        // and per-request regardless of how the flushes land.
        let (eng, mut gen) = engine(1, 4096);
        let donor = gen.next_request("ctr");
        let reqs: Vec<Request> = (0..40)
            .map(|_| {
                let mut r = gen.next_request("ctr");
                r.context = donor.context.clone();
                r
            })
            .collect();
        let handle = eng.router.resolve("ctr").unwrap();
        let model = handle.load();
        let rxs: Vec<_> = reqs.iter().map(|r| eng.submit(r.clone()).unwrap()).collect();
        let mut ws = Workspace::new();
        let cp = model.context_partial(&donor.context);
        for (req, rx) in reqs.iter().zip(rxs) {
            let resp = rx.recv().unwrap().unwrap();
            let mut want = Vec::new();
            model.predict_batch_with_partial(&cp, &req.candidates, &mut ws, &mut want);
            assert_eq!(resp.scores, want);
        }
        let stats = eng.shutdown();
        assert_eq!(stats.requests, 40);
        assert_eq!(stats.errors, 0);
        // every batch planned at least one group, never more groups
        // than requests
        assert!(stats.groups >= stats.batches);
        assert!(stats.groups <= stats.requests);
        // one partial per (batch, context): misses+hits == groups here
        assert_eq!(stats.cache_hits + stats.cache_misses, stats.groups);
    }

    #[test]
    fn oversized_group_is_chunked_by_the_workspace_cap() {
        // max_group_candidates 4 with a 5-request / 20-candidate shared
        // context: scores must still be bitwise the uncapped ones.
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg = Regressor::new(&cfg);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(reg.clone()));
        let mut gen = TraceGenerator::new(77, 6, 3, 1 << 10, 4);
        let donor = gen.next_request("ctr");
        let reqs: Vec<Request> = (0..5)
            .map(|_| {
                let mut r = gen.next_request("ctr");
                r.context = donor.context.clone();
                r
            })
            .collect();
        let mut ws = Workspace::new();
        let mut cache = ContextCache::new(64);
        let (capped, plan) = score_requests_coalesced(&router, &mut cache, &mut ws, 4, &reqs);
        let (uncapped, _) = score_requests_coalesced(
            &router,
            &mut cache,
            &mut ws,
            usize::MAX,
            &reqs,
        );
        assert_eq!(plan.groups, 1);
        assert_eq!(plan.coalesced_requests, 5);
        for (a, b) in capped.iter().zip(&uncapped) {
            assert_eq!(a.as_ref().unwrap().scores, b.as_ref().unwrap().scores);
        }
    }

    #[test]
    fn zero_candidate_member_scores_empty_in_coalesced_path() {
        // A zero-candidate request inside a shared-context group gets
        // Ok(scores: []) while its group-mates score normally; a
        // whole-group-of-empties also comes back Ok.
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg = Regressor::new(&cfg);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(reg.clone()));
        let mut gen = TraceGenerator::new(91, 6, 3, 1 << 10, 4);
        let full = gen.next_request("ctr");
        let mut empty = gen.next_request("ctr");
        empty.context = full.context.clone();
        empty.candidates.clear();
        let mut lone_empty = gen.next_request("ctr");
        lone_empty.candidates.clear();
        let reqs = vec![full.clone(), empty, lone_empty];
        let mut cache = ContextCache::new(64);
        let mut ws = Workspace::new();
        let (results, plan) =
            score_requests_coalesced(&router, &mut cache, &mut ws, 1024, &reqs);
        assert_eq!(plan.groups, 2);
        assert_eq!(results[1].as_ref().unwrap().scores, Vec::<f32>::new());
        assert_eq!(results[2].as_ref().unwrap().scores, Vec::<f32>::new());
        // the full group-mate scored bitwise the per-request path
        let cp = reg.context_partial(&full.context);
        let mut want = Vec::new();
        reg.predict_batch_with_partial(&cp, &full.candidates, &mut ws, &mut want);
        assert_eq!(results[0].as_ref().unwrap().scores, want);
    }

    #[test]
    fn obs_attached_engine_is_bit_identical_to_partial_path() {
        // Registry attached, tracer attached with sampling DISABLED
        // (every = 0): responses must be bitwise what the per-request
        // partial path computes — the observability plane observes,
        // never perturbs.
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let reg_model = Regressor::new(&cfg);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(reg_model.clone()));
        let registry = Arc::new(ObsRegistry::new());
        let obs = ObsOptions::with_registry(registry.clone())
            .tracer(RequestTracer::new(0, TraceSink::memory()));
        let eng = ServingEngine::start_with_obs(
            router,
            ServeConfig {
                workers: 1,
                max_batch: 64,
                max_wait_us: 100,
                context_cache_entries: 1024,
                ..ServeConfig::default()
            },
            obs,
        );
        let mut gen = TraceGenerator::new(61, 6, 3, 1 << 10, 4);
        let mut ws = Workspace::new();
        for _ in 0..50 {
            let req = gen.next_request("ctr");
            let resp = eng.score(req.clone()).unwrap();
            let cp = reg_model.context_partial(&req.context);
            let mut want = Vec::new();
            reg_model.predict_batch_with_partial(&cp, &req.candidates, &mut ws, &mut want);
            assert_eq!(resp.scores, want, "observability wiring perturbed scores");
        }
        eng.shutdown();
        // every request flowed through the shared registry...
        assert_eq!(registry.counter_value("fw_serve_requests_total"), Some(50));
        let total =
            registry.histogram_snapshot("fw_serve_stage_total_ns").unwrap();
        assert_eq!(total.count(), 50);
        let queue =
            registry.histogram_snapshot("fw_serve_stage_queue_ns").unwrap();
        assert_eq!(queue.count(), 50);
        // ...and one render exposes a scrapeable exposition
        let text = registry.render_prometheus();
        crate::testutil::check_prometheus_text(&text).expect("render well-formed");
        assert!(text.contains("fw_serve_stage_kernel_ns{quantile=\"0.99\"}"));
        assert!(text.contains("fw_serve_requests_total 50"));
    }

    #[test]
    fn sampled_tracing_emits_valid_jsonl_one_in_n() {
        let sink = TraceSink::memory();
        let obs = ObsOptions::default().tracer(RequestTracer::new(3, sink.clone()));
        let cfg = ModelConfig::deep_ffm(6, 2, 1 << 10, &[8]);
        let router = Router::new(1);
        router.register("ctr", ModelHandle::new(Regressor::new(&cfg)));
        let eng = ServingEngine::start_with_obs(
            router,
            ServeConfig {
                workers: 1,
                max_batch: 64,
                max_wait_us: 100,
                context_cache_entries: 1024,
                ..ServeConfig::default()
            },
            obs,
        );
        let mut gen = TraceGenerator::new(67, 6, 3, 1 << 10, 4);
        for _ in 0..30 {
            eng.score(gen.next_request("ctr")).unwrap();
        }
        eng.shutdown();
        let lines = sink.drain();
        // 1-in-3 over 30 requests = 10 sampled, one event per stage
        assert_eq!(lines.len(), 10 * Stage::ALL.len());
        let mut ids = std::collections::BTreeSet::new();
        let mut totals = 0;
        for line in &lines {
            let ev = crate::util::json::parse(line).expect("valid JSONL");
            assert_eq!(ev.get("event").as_str(), Some("stage"));
            assert_eq!(ev.get("model").as_str(), Some("ctr"));
            assert!(ev.get("ns").as_f64().is_some());
            assert_eq!(ev.get("group_key").as_str().map(|k| k.len()), Some(16));
            ids.insert(ev.get("trace").as_f64().unwrap() as u64);
            if ev.get("stage").as_str() == Some("total") {
                totals += 1;
            }
        }
        assert_eq!(ids.len(), 10, "each sampled request keeps one trace id");
        assert_eq!(totals, 10, "each sampled request closes with a total event");
    }

    #[test]
    fn stats_snapshot_is_consistent_at_one_boundary() {
        // Satellite: stats() must cut across all workers at one
        // boundary — counters monotone across in-flight snapshots, and
        // internally consistent (groups can never exceed requests in
        // any single snapshot, which a mid-merge race could show).
        let (eng, mut gen) = engine(2, 1024);
        let client = eng.client();
        let reqs: Vec<Request> = (0..300).map(|_| gen.next_request("ctr")).collect();
        let driver = std::thread::spawn(move || {
            for r in reqs {
                client.score(r).unwrap();
            }
        });
        let mut last_requests = 0u64;
        for _ in 0..50 {
            let s = eng.stats();
            assert!(s.requests >= last_requests, "requests went backwards");
            assert!(s.groups <= s.requests, "snapshot tore mid-merge");
            assert!(s.batches <= s.requests, "snapshot tore mid-merge");
            last_requests = s.requests;
        }
        driver.join().unwrap();
        // quiesced: snapshots agree and the queues are empty
        let a = eng.stats();
        let b = eng.stats();
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.groups, b.groups);
        assert_eq!(a.queue_depth, 0);
        let fin = eng.shutdown();
        assert_eq!(fin.requests, 300);
    }

    #[test]
    fn cache_hits_accumulate_on_zipf_contexts() {
        let (eng, mut gen) = engine(1, 4096);
        for _ in 0..500 {
            let req = gen.next_request("ctr");
            eng.score(req).unwrap();
        }
        let stats = eng.shutdown();
        assert!(
            stats.cache_hits > 100,
            "hit rate {} too low",
            stats.cache_hit_rate()
        );
    }
}
