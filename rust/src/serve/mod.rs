//! §5 — the serving layer.
//!
//! "Each request can be separated into context and candidates.  For all
//! candidates in the request, the context is the same" — the serving
//! types below encode that split directly, and the per-worker
//! [`context_cache`] exploits it.
//!
//! Components:
//! * [`ModelHandle`] — hot-swappable model slot (the §6 update pipeline
//!   swaps a new weight set in without pausing serving).
//! * [`router`] — model registry + context-affinity worker sharding.
//! * [`batcher`] — dynamic candidate batching with linger deadline.
//! * [`context_cache`] — radix-tree cache of partial forwards.
//! * [`server`] — the thread-pool serving engine with latency metrics.
//! * [`trace`] — synthetic production-traffic generator (Figures 4/5).

pub mod batcher;
pub mod context_cache;
pub mod router;
pub mod server;
pub mod trace;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::feature::FeatureSlot;
use crate::model::regressor::Regressor;

/// A scoring request: one shared context, many candidates.
#[derive(Clone, Debug)]
pub struct Request {
    /// Model to score with (registered name).
    pub model: String,
    /// Context feature slots (fields `0..C` of the model).
    pub context: Vec<FeatureSlot>,
    /// Candidate slot groups (fields `C..F` each).
    pub candidates: Vec<Vec<FeatureSlot>>,
}

/// Scores for one request's candidates, in order.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub scores: Vec<f32>,
}

/// Hot-swappable model slot.
///
/// Readers take a cheap `Arc` clone of the current model; the update
/// pipeline swaps in a new `Arc` atomically and bumps the version so
/// caches keyed on stale weights invalidate themselves.
#[derive(Clone)]
pub struct ModelHandle {
    inner: Arc<RwLock<Arc<Regressor>>>,
    version: Arc<AtomicU64>,
}

impl ModelHandle {
    pub fn new(reg: Regressor) -> Self {
        ModelHandle {
            inner: Arc::new(RwLock::new(Arc::new(reg))),
            version: Arc::new(AtomicU64::new(1)),
        }
    }

    /// Current model snapshot.
    pub fn load(&self) -> Arc<Regressor> {
        self.inner.read().expect("model lock poisoned").clone()
    }

    /// Swap in a new model (returns the new version).
    pub fn swap(&self, reg: Regressor) -> u64 {
        let mut slot = self.inner.write().expect("model lock poisoned");
        *slot = Arc::new(reg);
        self.version.fetch_add(1, Ordering::Release) + 1
    }

    /// Monotonic version, bumped on every swap.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn model_handle_swap_bumps_version() {
        let cfg = ModelConfig::linear(4, 256);
        let h = ModelHandle::new(Regressor::new(&cfg));
        assert_eq!(h.version(), 1);
        let m1 = h.load();
        let mut cfg2 = cfg.clone();
        cfg2.seed = 9;
        let v = h.swap(Regressor::new(&cfg2));
        assert_eq!(v, 2);
        assert_eq!(h.version(), 2);
        let m2 = h.load();
        // old snapshot still alive (readers never block swaps)
        assert_eq!(m1.cfg.seed, cfg.seed);
        assert_eq!(m2.cfg.seed, 9);
    }

    #[test]
    fn handle_clones_share_state() {
        let cfg = ModelConfig::linear(4, 256);
        let h = ModelHandle::new(Regressor::new(&cfg));
        let h2 = h.clone();
        h.swap(Regressor::new(&cfg));
        assert_eq!(h2.version(), 2);
    }
}
