//! The composed model (Figure 2): LR + FFM + MergeNormLayer + neural
//! block, with logistic loss and single-pass online learning.
//!
//! Forward spec (bit-identical in structure to `python/compile/model.py`
//! — the PJRT cross-check test holds the two to rtol 1e-5):
//!
//! ```text
//! lr_out  = Σ_f w_lr[bucket_f] · x_f
//! pairs   = DiagMask(FFM(w_ffm, x))              (upper triangle, row-major)
//! merged  = [lr_out, pairs...] / rms             (MergeNormLayer, eps 1e-6)
//! h       = ReLU MLP(merged)
//! logit   = h·w_out + b_out + lr_out             (residual LR)
//! p       = σ(logit)
//! ```
//!
//! For `Architecture::Ffm`: `logit = lr_out + Σ pairs`;
//! for `Architecture::Linear`: `logit = lr_out`.

use crate::config::{Architecture, ModelConfig};
use crate::feature::{Example, FeatureSlot};
use crate::model::block_neural::NeuralBlock;
use crate::model::optimizer::{AdaGrad, UpdateRule};
use crate::model::weights::{Layout, WeightPool};
use crate::model::{block_ffm, block_lr, Workspace};
use crate::simd::dot;
use crate::util::math::sigmoid;

/// MergeNormLayer epsilon — part of the cross-layer ABI.
pub const MERGE_NORM_EPS: f32 = 1e-6;

/// Index of pair (i, j), i < j, in the row-major upper triangle.
#[inline]
pub fn pair_index(i: usize, j: usize, fields: usize) -> usize {
    debug_assert!(i < j && j < fields);
    i * (2 * fields - i - 1) / 2 + (j - i - 1)
}

/// Clamp a requested serving architecture to what the model has: a
/// model can be scored *down* the Linear < Ffm < DeepFfm ladder (blocks
/// it owns are skipped) but never up (blocks it lacks cannot be
/// conjured).
#[inline]
fn clamp_arch(model: Architecture, requested: Architecture) -> Architecture {
    fn rank(a: Architecture) -> u8 {
        match a {
            Architecture::Linear => 0,
            Architecture::Ffm => 1,
            Architecture::DeepFfm => 2,
        }
    }
    if rank(requested) < rank(model) {
        requested
    } else {
        model
    }
}

/// Cached partial forward state for a request context (§5).
#[derive(Clone, Debug, PartialEq)]
pub struct ContextPartial {
    /// Number of context fields C (fields 0..C).
    pub ctx_fields: usize,
    /// LR contribution of the context fields.
    pub lr_sum: f32,
    /// Pair values for context×context pairs, indexed by
    /// `pair_index(i, j, fields)` order (compacted, C*(C-1)/2 entries).
    pub ctx_pairs: Vec<f32>,
    /// Context slots (buckets + values) for the ctx×candidate pairs.
    pub slots: Vec<FeatureSlot>,
}

/// The online regressor.
#[derive(Clone, Debug)]
pub struct Regressor {
    pub cfg: ModelConfig,
    pub layout: Layout,
    pub pool: WeightPool,
    nn: Option<NeuralBlock>,
}

impl Regressor {
    pub fn new(cfg: &ModelConfig) -> Self {
        cfg.validate().expect("invalid model config");
        let layout = Layout::new(cfg);
        let pool = WeightPool::init(cfg, &layout);
        let nn = match cfg.arch {
            Architecture::DeepFfm => Some(NeuralBlock::new(&layout, cfg.sparse_updates)),
            _ => None,
        };
        Regressor { cfg: cfg.clone(), layout, pool, nn }
    }

    /// Rebuild from existing parts (model loading).
    pub fn from_parts(cfg: ModelConfig, pool: WeightPool) -> Self {
        let layout = Layout::new(&cfg);
        assert_eq!(pool.weights.len(), layout.total, "pool/layout mismatch");
        let nn = match cfg.arch {
            Architecture::DeepFfm => Some(NeuralBlock::new(&layout, cfg.sparse_updates)),
            _ => None,
        };
        Regressor { cfg, layout, pool, nn }
    }

    /// Toggle §4.3 sparse updates (Table 3's two arms).
    pub fn set_sparse_updates(&mut self, sparse: bool) {
        self.cfg.sparse_updates = sparse;
        if let Some(nn) = &mut self.nn {
            nn.sparse = sparse;
        }
    }

    // ------------------------------------------------------------ forward

    /// Predict the click probability for an example.
    pub fn predict(&self, ex: &Example, ws: &mut Workspace) -> f32 {
        debug_assert_eq!(ex.slots.len(), self.cfg.fields);
        let w = &self.pool.weights;
        let lr_out = block_lr::forward(w, &self.layout, ex);
        if self.cfg.arch == Architecture::Linear {
            ws.lr_out = lr_out;
            ws.logit = lr_out;
            return sigmoid(lr_out);
        }
        ws.pairs.resize(self.cfg.pairs(), 0.0);
        block_ffm::forward(
            w,
            &self.layout,
            self.cfg.fields,
            self.cfg.latent_dim,
            ex,
            &mut ws.pairs,
        );
        self.finish_forward(lr_out, ws)
    }

    /// Shared tail: MergeNorm + neural head (or plain FFM sum).
    /// `ws.pairs` must already hold the pair interactions.
    fn finish_forward(&self, lr_out: f32, ws: &mut Workspace) -> f32 {
        ws.lr_out = lr_out;
        match self.cfg.arch {
            Architecture::Linear => unreachable!(),
            Architecture::Ffm => {
                let s: f32 = ws.pairs.iter().sum();
                ws.logit = lr_out + s;
            }
            Architecture::DeepFfm => {
                let d = self.cfg.merged_dim();
                ws.merged_raw.resize(d, 0.0);
                ws.merged_raw[0] = lr_out;
                ws.merged_raw[1..].copy_from_slice(&ws.pairs);
                let ssq = dot::dot(&ws.merged_raw, &ws.merged_raw);
                let rms = (ssq / d as f32 + MERGE_NORM_EPS).sqrt();
                ws.rms = rms;
                ws.merged.resize(d, 0.0);
                let inv = 1.0 / rms;
                for (m, &r) in ws.merged.iter_mut().zip(&ws.merged_raw) {
                    *m = r * inv;
                }
                let nn = self.nn.as_ref().expect("deepffm has nn");
                let head =
                    nn.forward(&self.pool.weights, &ws.merged, &mut ws.activations);
                ws.logit = head + lr_out;
            }
        }
        sigmoid(ws.logit)
    }

    // ----------------------------------------------------------- learning

    /// One online learning step; returns the *pre-update* prediction
    /// (progressive validation score).
    pub fn learn(&mut self, ex: &Example, ws: &mut Workspace) -> f32 {
        debug_assert!(ex.is_labeled(), "learn needs a labeled example");
        let p = self.predict(ex, ws);
        let d = (p - ex.label) * ex.importance; // dL/dlogit
        let mut lr_rule = AdaGrad::new(self.cfg.lr, self.cfg.power_t, self.cfg.l2);
        let mut ffm_rule =
            AdaGrad::new(self.cfg.ffm_lr, self.cfg.power_t, self.cfg.l2);
        let mut nn_rule = AdaGrad::new(self.cfg.nn_lr, self.cfg.power_t, self.cfg.l2);
        self.backward(ex, ws, d, &mut lr_rule, &mut ffm_rule, &mut nn_rule);
        p
    }

    /// Backward pass with caller-supplied update rules (used by tests
    /// with a [`GradRecorder`](crate::model::optimizer::GradRecorder)).
    pub fn backward<U: UpdateRule>(
        &mut self,
        ex: &Example,
        ws: &mut Workspace,
        d: f32,
        lr_rule: &mut U,
        ffm_rule: &mut U,
        nn_rule: &mut U,
    ) {
        let layout = &self.layout;
        let (weights, acc) = (&mut self.pool.weights, &mut self.pool.acc);
        debug_assert!(!acc.is_empty(), "inference pool cannot learn");
        match self.cfg.arch {
            Architecture::Linear => {
                block_lr::backward(weights, acc, layout, ex, d, lr_rule);
            }
            Architecture::Ffm => {
                // logit = lr_out + Σ pairs -> every pair grad is d
                let np = self.cfg.pairs();
                ws.dmerged.clear();
                ws.dmerged.resize(np, d);
                block_ffm::backward(
                    weights,
                    acc,
                    layout,
                    self.cfg.fields,
                    self.cfg.latent_dim,
                    ex,
                    &ws.dmerged,
                    ffm_rule,
                );
                block_lr::backward(weights, acc, layout, ex, d, lr_rule);
            }
            Architecture::DeepFfm => {
                let dim = self.cfg.merged_dim();
                ws.dmerged.resize(dim, 0.0);
                let nn = self.nn.as_mut().expect("deepffm has nn");
                nn.backward(
                    weights,
                    acc,
                    &ws.merged,
                    &ws.activations,
                    d,
                    &mut ws.dmerged,
                    &mut ws.grad_bufs,
                    nn_rule,
                );
                // RMS-norm backward: draw = (g - m * <g,m>/D) / rms
                let s = dot::dot(&ws.dmerged, &ws.merged);
                let inv = 1.0 / ws.rms;
                let sd = s / dim as f32;
                // reuse dmerged in place as draw
                for i in 0..dim {
                    ws.dmerged[i] = (ws.dmerged[i] - ws.merged[i] * sd) * inv;
                }
                let d_lr = d + ws.dmerged[0]; // residual + through merge
                block_ffm::backward(
                    weights,
                    acc,
                    layout,
                    self.cfg.fields,
                    self.cfg.latent_dim,
                    ex,
                    &ws.dmerged[1..],
                    ffm_rule,
                );
                block_lr::backward(weights, acc, layout, ex, d_lr, lr_rule);
            }
        }
    }

    // ------------------------------------------------- batched training

    /// Forward pass over a micro-batch of full (all-fields) examples.
    ///
    /// The sparse blocks run per example — LR sums and FFM pairs are
    /// hashed-gather bound, not FLOP bound — while the dense tower
    /// (where §4.3 says the FLOPs live) runs batch-strided through
    /// [`NeuralBlock::forward_batch`]'s GEMM-lite, streaming each MLP
    /// weight row once per 4-example register block.  `scores` is
    /// cleared and receives one probability per example, in order.
    ///
    /// A single example (`exs.len() == 1`) delegates to [`predict`]
    /// (Self::predict), so the B = 1 path is **bit-identical** to the
    /// per-example path by construction.
    pub fn predict_batch(
        &self,
        exs: &[Example],
        ws: &mut Workspace,
        scores: &mut Vec<f32>,
    ) {
        let bsz = exs.len();
        scores.clear();
        if bsz == 0 {
            return;
        }
        if bsz == 1 {
            scores.push(self.predict(&exs[0], ws));
            return;
        }
        let w = &self.pool.weights;
        ws.batch_lr.clear();
        ws.batch_lr.reserve(bsz);
        for ex in exs {
            debug_assert_eq!(ex.slots.len(), self.cfg.fields);
            ws.batch_lr.push(block_lr::forward(w, &self.layout, ex));
        }
        if self.cfg.arch == Architecture::Linear {
            ws.lr_out = ws.batch_lr[bsz - 1];
            ws.logit = ws.lr_out;
            scores.extend(ws.batch_lr.iter().map(|&lr| sigmoid(lr)));
            return;
        }
        let np = self.cfg.pairs();
        ws.pairs.resize(bsz * np, 0.0);
        for (b, ex) in exs.iter().enumerate() {
            block_ffm::forward(
                w,
                &self.layout,
                self.cfg.fields,
                self.cfg.latent_dim,
                ex,
                &mut ws.pairs[b * np..(b + 1) * np],
            );
        }
        match self.cfg.arch {
            Architecture::Linear => unreachable!(),
            Architecture::Ffm => {
                for b in 0..bsz {
                    let s: f32 = ws.pairs[b * np..(b + 1) * np].iter().sum();
                    let logit = ws.batch_lr[b] + s;
                    scores.push(sigmoid(logit));
                    if b == bsz - 1 {
                        ws.lr_out = ws.batch_lr[b];
                        ws.logit = logit;
                    }
                }
            }
            Architecture::DeepFfm => {
                // Batched MergeNorm with *per-row* RMS kept for the
                // backward (the serving path only keeps the last one).
                // Deliberately NOT shared with predict_batch_with_
                // partial's tail: training computes each row's ssq via
                // the same dot::dot call `finish_forward` uses, so the
                // micro-batch forward stays on per-example arithmetic
                // (a gate flip near ReLU 0 would change the §4.3
                // sparse backward), while serving batches the ssq via
                // rowwise_sumsq.  Keep all three tails in sync on any
                // MergeNorm change.
                let d = self.cfg.merged_dim();
                ws.merged_raw.resize(bsz * d, 0.0);
                for b in 0..bsz {
                    ws.merged_raw[b * d] = ws.batch_lr[b];
                    ws.merged_raw[b * d + 1..(b + 1) * d]
                        .copy_from_slice(&ws.pairs[b * np..(b + 1) * np]);
                }
                ws.merged.resize(bsz * d, 0.0);
                ws.batch_rms.clear();
                ws.batch_rms.reserve(bsz);
                for b in 0..bsz {
                    let raw = &ws.merged_raw[b * d..(b + 1) * d];
                    let ssq = dot::dot(raw, raw);
                    let rms = (ssq / d as f32 + MERGE_NORM_EPS).sqrt();
                    ws.batch_rms.push(rms);
                    let inv = 1.0 / rms;
                    for (m, &r) in
                        ws.merged[b * d..(b + 1) * d].iter_mut().zip(raw)
                    {
                        *m = r * inv;
                    }
                }
                let nn = self.nn.as_ref().expect("deepffm has nn");
                nn.forward_batch(
                    w,
                    &ws.merged,
                    bsz,
                    &mut ws.activations,
                    &mut ws.batch_heads,
                );
                for b in 0..bsz {
                    let logit = ws.batch_heads[b] + ws.batch_lr[b];
                    scores.push(sigmoid(logit));
                    if b == bsz - 1 {
                        ws.lr_out = ws.batch_lr[b];
                        ws.logit = logit;
                        ws.rms = ws.batch_rms[b];
                    }
                }
            }
        }
    }

    /// One minibatch learning step over `exs`; `scores` is cleared and
    /// receives the *pre-update* prediction per example (progressive
    /// validation, same contract as [`learn`](Self::learn)).
    ///
    /// Semantics: the forward runs for the whole micro-batch at batch-
    /// start weights ([`predict_batch`](Self::predict_batch)); the
    /// sparse LR/FFM blocks then apply per-example updates (hashed
    /// collisions are the Hogwild contract — §4.2 — and batching them
    /// would buy nothing), while the dense neural tower applies one
    /// summed update per coordinate through
    /// [`NeuralBlock::backward_batch`]'s transposed GEMM pair.  A
    /// 1-example batch delegates to [`learn`](Self::learn) and is
    /// bit-identical to it.
    pub fn learn_batch(
        &mut self,
        exs: &[Example],
        ws: &mut Workspace,
        scores: &mut Vec<f32>,
    ) {
        if exs.len() == 1 {
            scores.clear();
            scores.push(self.learn(&exs[0], ws));
            return;
        }
        self.predict_batch(exs, ws, scores);
        if exs.is_empty() {
            return;
        }
        ws.batch_d.clear();
        for (ex, &p) in exs.iter().zip(scores.iter()) {
            debug_assert!(ex.is_labeled(), "learn_batch needs labeled examples");
            ws.batch_d.push((p - ex.label) * ex.importance);
        }
        let mut lr_rule = AdaGrad::new(self.cfg.lr, self.cfg.power_t, self.cfg.l2);
        let mut ffm_rule =
            AdaGrad::new(self.cfg.ffm_lr, self.cfg.power_t, self.cfg.l2);
        let mut nn_rule = AdaGrad::new(self.cfg.nn_lr, self.cfg.power_t, self.cfg.l2);
        let d = std::mem::take(&mut ws.batch_d);
        self.backward_batch(exs, ws, &d, &mut lr_rule, &mut ffm_rule, &mut nn_rule);
        ws.batch_d = d;
    }

    /// Batched backward with caller-supplied update rules (tests pass
    /// [`GradRecorder`](crate::model::optimizer::GradRecorder)s to
    /// compare against per-example gradients).  Requires the workspace
    /// state left by [`predict_batch`](Self::predict_batch) over the
    /// same examples; `d` holds per-example dL/dlogit.  A 1-example
    /// batch delegates to [`backward`](Self::backward).
    pub fn backward_batch<U: UpdateRule>(
        &mut self,
        exs: &[Example],
        ws: &mut Workspace,
        d: &[f32],
        lr_rule: &mut U,
        ffm_rule: &mut U,
        nn_rule: &mut U,
    ) {
        let bsz = exs.len();
        debug_assert_eq!(d.len(), bsz);
        if bsz == 0 {
            return;
        }
        if bsz == 1 {
            self.backward(&exs[0], ws, d[0], lr_rule, ffm_rule, nn_rule);
            return;
        }
        let layout = &self.layout;
        let (weights, acc) = (&mut self.pool.weights, &mut self.pool.acc);
        debug_assert!(!acc.is_empty(), "inference pool cannot learn");
        match self.cfg.arch {
            Architecture::Linear => {
                for (ex, &db) in exs.iter().zip(d) {
                    block_lr::backward(weights, acc, layout, ex, db, lr_rule);
                }
            }
            Architecture::Ffm => {
                let np = self.cfg.pairs();
                for (ex, &db) in exs.iter().zip(d) {
                    ws.dmerged.clear();
                    ws.dmerged.resize(np, db);
                    block_ffm::backward(
                        weights,
                        acc,
                        layout,
                        self.cfg.fields,
                        self.cfg.latent_dim,
                        ex,
                        &ws.dmerged,
                        ffm_rule,
                    );
                    block_lr::backward(weights, acc, layout, ex, db, lr_rule);
                }
            }
            Architecture::DeepFfm => {
                let dim = self.cfg.merged_dim();
                ws.dmerged.clear();
                ws.dmerged.resize(bsz * dim, 0.0);
                let nn = self.nn.as_mut().expect("deepffm has nn");
                nn.backward_batch(
                    weights,
                    acc,
                    &ws.merged,
                    bsz,
                    &ws.activations,
                    d,
                    &mut ws.dmerged,
                    &mut ws.batch_grads,
                    nn_rule,
                );
                // Per-row RMS-norm backward, then per-example sparse
                // backward through the FFM and LR blocks.
                for (b, (ex, &db)) in exs.iter().zip(d).enumerate() {
                    let (merged, dmerged) = (&ws.merged, &mut ws.dmerged);
                    let mrow = &merged[b * dim..(b + 1) * dim];
                    let grow = &mut dmerged[b * dim..(b + 1) * dim];
                    let s = dot::dot(grow, mrow);
                    let inv = 1.0 / ws.batch_rms[b];
                    let sd = s / dim as f32;
                    for (g, &m) in grow.iter_mut().zip(mrow) {
                        *g = (*g - m * sd) * inv;
                    }
                    let d_lr = db + grow[0];
                    block_ffm::backward(
                        weights,
                        acc,
                        layout,
                        self.cfg.fields,
                        self.cfg.latent_dim,
                        ex,
                        &grow[1..],
                        ffm_rule,
                    );
                    block_lr::backward(weights, acc, layout, ex, d_lr, lr_rule);
                }
            }
        }
    }

    // ----------------------------------------------- context caching (§5)

    /// Precompute the reusable part of a request context: fields
    /// `0..ctx_slots.len()` of the model.
    pub fn context_partial(&self, ctx_slots: &[FeatureSlot]) -> ContextPartial {
        let c = ctx_slots.len();
        debug_assert!(c <= self.cfg.fields);
        let w = &self.pool.weights;
        let mut lr_sum = 0.0f32;
        for s in ctx_slots {
            if s.value != 0.0 {
                lr_sum += w[self.layout.lr_idx(s.bucket)] * s.value;
            }
        }
        let mut ctx_pairs = Vec::with_capacity(c.saturating_sub(1) * c / 2);
        if self.cfg.arch != Architecture::Linear {
            let k = self.cfg.latent_dim;
            let fk = self.cfg.fields * k;
            for i in 0..c {
                for j in (i + 1)..c {
                    let (si, sj) = (&ctx_slots[i], &ctx_slots[j]);
                    if si.value == 0.0 || sj.value == 0.0 {
                        ctx_pairs.push(0.0);
                        continue;
                    }
                    let ri = self.layout.ffm_off + si.bucket as usize * fk + j * k;
                    let rj = self.layout.ffm_off + sj.bucket as usize * fk + i * k;
                    ctx_pairs.push(
                        dot::dot(&w[ri..ri + k], &w[rj..rj + k])
                            * si.value
                            * sj.value,
                    );
                }
            }
        }
        ContextPartial {
            ctx_fields: c,
            lr_sum,
            ctx_pairs,
            slots: ctx_slots.to_vec(),
        }
    }

    /// Score one candidate given a cached context partial.
    /// `cand_slots` covers fields `C..fields` (in order).
    ///
    /// Delegates to [`predict_batch_with_partial`]
    /// (Self::predict_batch_with_partial) with B = 1, so the single-
    /// candidate path is exactly the batched path (bit-identical — the
    /// kernels guarantee batch-size invariance) and the context-slot
    /// copy the old per-candidate path performed is gone.
    pub fn predict_with_partial(
        &self,
        cp: &ContextPartial,
        cand_slots: &[FeatureSlot],
        ws: &mut Workspace,
    ) -> f32 {
        let mut scores = std::mem::take(&mut ws.batch_scores);
        self.predict_batch_with_partial(
            cp,
            std::slice::from_ref(&cand_slots),
            ws,
            &mut scores,
        );
        let p = scores[0];
        ws.batch_scores = scores;
        p
    }

    /// Score **all** candidates of a request in one batched pass (the
    /// tentpole of the request-level batching PR).
    ///
    /// Per-request work is paid once instead of once per candidate: one
    /// candidate-slot flatten, one shared prefetch pass, one SIMD
    /// dispatch per kernel, and — through the field-outer
    /// [`block_ffm::forward_partial_batch`] loop and the
    /// register-blocked GEMM-lite of
    /// [`crate::simd::batch::matmul_rowmajor`] — each context latent
    /// strip and each MLP weight row is loaded once per batch block
    /// instead of once per candidate.  (The ctx×ctx values still land
    /// in every candidate's pair stride, but as one contiguous
    /// `copy_from_slice` per context row rather than a recompute.)
    ///
    /// `scores` is cleared and receives one probability per candidate,
    /// in order.  All workspace buffers are reused batch-strided with
    /// zero allocation at steady state.
    pub fn predict_batch_with_partial<S: AsRef<[FeatureSlot]>>(
        &self,
        cp: &ContextPartial,
        cands: &[S],
        ws: &mut Workspace,
        scores: &mut Vec<f32>,
    ) {
        self.predict_batch_with_partial_as(self.cfg.arch, cp, cands, ws, scores)
    }

    /// [`predict_batch_with_partial`](Self::predict_batch_with_partial)
    /// scored **as** `arch` — the degraded-mode hook.  The serving
    /// engine's overload controller walks the DeepFFM→FFM→LR ladder by
    /// passing a cheaper architecture here: `Ffm` drops the neural head
    /// (logit = lr + Σ pairs), `Linear` drops the pairs too (logit =
    /// lr).  `arch` is clamped to the model's own architecture (a model
    /// can only be served *down* the ladder — its missing blocks cannot
    /// be conjured), so passing `self.cfg.arch` or anything above it is
    /// bit-identical to the plain call.  The [`ContextPartial`] is
    /// level-independent: one cached partial serves every rung.
    pub fn predict_batch_with_partial_as<S: AsRef<[FeatureSlot]>>(
        &self,
        arch: Architecture,
        cp: &ContextPartial,
        cands: &[S],
        ws: &mut Workspace,
        scores: &mut Vec<f32>,
    ) {
        let arch = clamp_arch(self.cfg.arch, arch);
        let f = self.cfg.fields;
        let c = cp.ctx_fields;
        debug_assert!(c <= f, "context wider than the model");
        let cw = f - c;
        let bsz = cands.len();
        scores.clear();
        if bsz == 0 {
            return;
        }
        let w = &self.pool.weights;
        // Batched LR: cached context sum + per-candidate sums.
        ws.batch_lr.clear();
        ws.batch_lr.reserve(bsz);
        for cand in cands {
            let cs = cand.as_ref();
            debug_assert_eq!(cs.len(), cw);
            let mut lr = cp.lr_sum;
            for s in cs {
                if s.value != 0.0 {
                    lr += w[self.layout.lr_idx(s.bucket)] * s.value;
                }
            }
            ws.batch_lr.push(lr);
        }
        if arch == Architecture::Linear {
            ws.lr_out = ws.batch_lr[bsz - 1];
            ws.logit = ws.lr_out;
            scores.extend(ws.batch_lr.iter().map(|&lr| sigmoid(lr)));
            return;
        }
        let k = self.cfg.latent_dim;
        let np = self.cfg.pairs();
        ws.pairs.resize(bsz * np, 0.0);
        // ctx×ctx from the cache: one contiguous copy per context row
        // per candidate stride.
        for b in 0..bsz {
            let pb = b * np;
            let mut src = 0usize;
            for i in 0..c {
                let n = c - i - 1;
                let dst = pb + i * (2 * f - i - 1) / 2;
                ws.pairs[dst..dst + n].copy_from_slice(&cp.ctx_pairs[src..src + n]);
                src += n;
            }
        }
        if cw > 0 {
            // Flatten candidate slots once per request (the context
            // slots stay in the cached partial — never re-copied per
            // candidate), then ctx×cand and cand×cand for the whole
            // batch, field-outer.  With cw == 0 (context covers all
            // fields) every pair came from the cache above.
            ws.cand_slots.clear();
            for cand in cands {
                ws.cand_slots.extend_from_slice(cand.as_ref());
            }
            block_ffm::forward_partial_batch(
                w,
                &self.layout,
                f,
                k,
                c,
                &cp.slots,
                &ws.cand_slots,
                &mut ws.pairs,
            );
        }
        match arch {
            Architecture::Linear => unreachable!(),
            Architecture::Ffm => {
                ws.batch_acc.resize(bsz, 0.0);
                crate::simd::batch::rowwise_sum(
                    &ws.pairs,
                    bsz,
                    np,
                    &mut ws.batch_acc,
                );
                for b in 0..bsz {
                    let logit = ws.batch_lr[b] + ws.batch_acc[b];
                    scores.push(sigmoid(logit));
                    if b == bsz - 1 {
                        ws.lr_out = ws.batch_lr[b];
                        ws.logit = logit;
                    }
                }
            }
            Architecture::DeepFfm => {
                // Batched MergeNorm: assemble B strided [lr, pairs…]
                // rows, one batched sum-of-squares, per-row normalize.
                let d = self.cfg.merged_dim();
                ws.merged_raw.resize(bsz * d, 0.0);
                for b in 0..bsz {
                    ws.merged_raw[b * d] = ws.batch_lr[b];
                    ws.merged_raw[b * d + 1..(b + 1) * d]
                        .copy_from_slice(&ws.pairs[b * np..(b + 1) * np]);
                }
                ws.batch_acc.resize(bsz, 0.0);
                crate::simd::batch::rowwise_sumsq(
                    &ws.merged_raw,
                    bsz,
                    d,
                    &mut ws.batch_acc,
                );
                ws.merged.resize(bsz * d, 0.0);
                for b in 0..bsz {
                    let rms = (ws.batch_acc[b] / d as f32 + MERGE_NORM_EPS).sqrt();
                    let inv = 1.0 / rms;
                    for (m, &r) in ws.merged[b * d..(b + 1) * d]
                        .iter_mut()
                        .zip(&ws.merged_raw[b * d..(b + 1) * d])
                    {
                        *m = r * inv;
                    }
                    if b == bsz - 1 {
                        ws.rms = rms;
                    }
                }
                let nn = self.nn.as_ref().expect("deepffm has nn");
                nn.forward_batch(
                    w,
                    &ws.merged,
                    bsz,
                    &mut ws.activations,
                    &mut ws.batch_heads,
                );
                for b in 0..bsz {
                    let logit = ws.batch_heads[b] + ws.batch_lr[b];
                    scores.push(sigmoid(logit));
                    if b == bsz - 1 {
                        ws.lr_out = ws.batch_lr[b];
                        ws.logit = logit;
                    }
                }
            }
        }
    }

    /// [`predict_batch_with_partial`](Self::predict_batch_with_partial)
    /// with a workspace cap: the slate is scored in consecutive chunks
    /// of at most `cap` candidates, so a union slate coalesced from
    /// many requests (the cross-request serving path) cannot grow the
    /// batch-strided workspace buffers without bound.  By the kernels'
    /// batch-size-invariance contract, chunked scoring is bit-identical
    /// to one uncapped pass — pinned by
    /// `capped_scoring_is_chunking_invariant` and the
    /// `prop_grouped_scoring_matches_per_request` property test.
    ///
    /// `scores` is cleared and receives one probability per candidate,
    /// in order.  `cap == 0` is treated as 1.
    pub fn predict_batch_with_partial_capped<S: AsRef<[FeatureSlot]>>(
        &self,
        cp: &ContextPartial,
        cands: &[S],
        cap: usize,
        ws: &mut Workspace,
        scores: &mut Vec<f32>,
    ) {
        self.predict_batch_with_partial_capped_as(self.cfg.arch, cp, cands, cap, ws, scores)
    }

    /// [`predict_batch_with_partial_capped`]
    /// (Self::predict_batch_with_partial_capped) scored as `arch` (see
    /// [`predict_batch_with_partial_as`]
    /// (Self::predict_batch_with_partial_as) — clamped to the model's
    /// own architecture, chunking stays bit-identical per rung).
    pub fn predict_batch_with_partial_capped_as<S: AsRef<[FeatureSlot]>>(
        &self,
        arch: Architecture,
        cp: &ContextPartial,
        cands: &[S],
        cap: usize,
        ws: &mut Workspace,
        scores: &mut Vec<f32>,
    ) {
        let cap = cap.max(1);
        if cands.len() <= cap {
            self.predict_batch_with_partial_as(arch, cp, cands, ws, scores);
            return;
        }
        scores.clear();
        scores.reserve(cands.len());
        let mut chunk = std::mem::take(&mut ws.group_scores);
        for cs in cands.chunks(cap) {
            self.predict_batch_with_partial_as(arch, cp, cs, ws, &mut chunk);
            scores.extend_from_slice(&chunk);
        }
        ws.group_scores = chunk;
    }

    /// Total parameter count (inference weights).
    pub fn num_weights(&self) -> usize {
        self.layout.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::eval::RollingAuc;
    use crate::model::optimizer::GradRecorder;
    use crate::util::math::logloss;

    fn tiny_cfg(arch: Architecture) -> ModelConfig {
        let mut cfg = match arch {
            Architecture::Linear => ModelConfig::linear(4, 256),
            Architecture::Ffm => ModelConfig::ffm(4, 2, 256),
            Architecture::DeepFfm => ModelConfig::deep_ffm(4, 2, 256, &[8]),
        };
        cfg.seed = 77;
        cfg
    }

    fn stream() -> SyntheticStream {
        SyntheticStream::with_buckets(DatasetSpec::tiny(), 21, 256)
    }

    #[test]
    fn pair_index_rowmajor() {
        let f = 5;
        let mut expect = 0;
        for i in 0..f {
            for j in (i + 1)..f {
                assert_eq!(pair_index(i, j, f), expect);
                expect += 1;
            }
        }
        assert_eq!(expect, f * (f - 1) / 2);
    }

    #[test]
    fn predictions_in_unit_interval() {
        for arch in [Architecture::Linear, Architecture::Ffm, Architecture::DeepFfm] {
            let r = Regressor::new(&tiny_cfg(arch));
            let mut ws = Workspace::new();
            let mut s = stream();
            for _ in 0..50 {
                let p = r.predict(&s.next_example(), &mut ws);
                assert!((0.0..=1.0).contains(&p), "{arch:?} p={p}");
                assert!(p.is_finite());
            }
        }
    }

    #[test]
    fn full_gradient_matches_finite_difference_deepffm() {
        let cfg = tiny_cfg(Architecture::DeepFfm);
        let mut reg = Regressor::new(&cfg);
        let mut s = stream();
        let ex = s.next_example();
        let mut ws = Workspace::new();
        // loss(w) with frozen structure
        let snapshot = reg.clone();
        let ex_c = ex.clone();
        let loss = move |weights: &[f32]| -> f64 {
            let mut r2 = snapshot.clone();
            r2.pool.weights = weights.to_vec();
            let mut w2 = Workspace::new();
            let p = r2.predict(&ex_c, &mut w2);
            logloss(p, ex_c.label)
        };
        let w0 = reg.pool.weights.clone();
        let p = reg.predict(&ex, &mut ws);
        let d = p - ex.label;
        let mut rec_lr = GradRecorder::default();
        let mut rec_ffm = GradRecorder::default();
        let mut rec_nn = GradRecorder::default();
        reg.backward(&ex, &mut ws, d, &mut rec_lr, &mut rec_ffm, &mut rec_nn);
        let mut analytic = rec_lr.dense(reg.layout.total);
        for (a, b) in analytic.iter_mut().zip(rec_ffm.dense(reg.layout.total)) {
            *a += b;
        }
        for (a, b) in analytic.iter_mut().zip(rec_nn.dense(reg.layout.total)) {
            *a += b;
        }
        let mut checked = 0;
        for idx in 0..reg.layout.total {
            if analytic[idx].abs() < 1e-8 {
                continue;
            }
            // scale eps down for steep coordinates: the quadratic
            // truncation error of the central difference grows with
            // curvature, which tracks |grad| under sigmoid+logloss
            let eps: f32 = if analytic[idx].abs() > 5.0 { 1e-4 } else { 1e-3 };
            let mut wp = w0.clone();
            wp[idx] += eps;
            let mut wm = w0.clone();
            wm[idx] -= eps;
            let numeric = ((loss(&wp) - loss(&wm)) / (2.0 * eps as f64)) as f32;
            assert!(
                (numeric - analytic[idx]).abs() < 4e-2 * (1.0 + numeric.abs()),
                "idx={idx} numeric={numeric} analytic={}",
                analytic[idx]
            );
            checked += 1;
            if checked > 120 {
                break; // enough coverage, keep the test fast
            }
        }
        assert!(checked >= 30, "only {checked} coords checked");
    }

    #[test]
    fn full_gradient_matches_finite_difference_ffm() {
        let cfg = tiny_cfg(Architecture::Ffm);
        let mut reg = Regressor::new(&cfg);
        let mut s = stream();
        let ex = s.next_example();
        let mut ws = Workspace::new();
        let snapshot = reg.clone();
        let ex_c = ex.clone();
        let loss = move |weights: &[f32]| -> f64 {
            let mut r2 = snapshot.clone();
            r2.pool.weights = weights.to_vec();
            let mut w2 = Workspace::new();
            logloss(r2.predict(&ex_c, &mut w2), ex_c.label)
        };
        let w0 = reg.pool.weights.clone();
        let p = reg.predict(&ex, &mut ws);
        let d = p - ex.label;
        let mut rec_lr = GradRecorder::default();
        let mut rec_ffm = GradRecorder::default();
        let mut rec_nn = GradRecorder::default();
        reg.backward(&ex, &mut ws, d, &mut rec_lr, &mut rec_ffm, &mut rec_nn);
        let mut analytic = rec_lr.dense(reg.layout.total);
        for (a, b) in analytic.iter_mut().zip(rec_ffm.dense(reg.layout.total)) {
            *a += b;
        }
        for (a, b) in analytic.iter_mut().zip(rec_nn.dense(reg.layout.total)) {
            *a += b;
        }
        let eps = 1e-3f32;
        let mut checked = 0;
        for idx in 0..reg.layout.total {
            if analytic[idx].abs() < 1e-8 {
                continue;
            }
            let mut wp = w0.clone();
            wp[idx] += eps;
            let mut wm = w0.clone();
            wm[idx] -= eps;
            let numeric = ((loss(&wp) - loss(&wm)) / (2.0 * eps as f64)) as f32;
            assert!(
                (numeric - analytic[idx]).abs() < 2e-2 * (1.0 + numeric.abs()),
                "idx={idx} numeric={numeric} analytic={}",
                analytic[idx]
            );
            checked += 1;
        }
        assert!(checked >= 10);
    }

    #[test]
    fn learning_beats_base_rate() {
        for arch in [Architecture::Linear, Architecture::Ffm, Architecture::DeepFfm] {
            let mut reg = Regressor::new(&tiny_cfg(arch));
            let mut ws = Workspace::new();
            let mut s = stream();
            let mut roll = RollingAuc::new(2000);
            for _ in 0..20_000 {
                let ex = s.next_example();
                let p = reg.learn(&ex, &mut ws);
                roll.add(p, ex.label);
            }
            let late: Vec<f64> =
                roll.points.iter().rev().take(4).cloned().collect();
            let avg = late.iter().sum::<f64>() / late.len() as f64;
            assert!(avg > 0.58, "{arch:?} late AUC {avg}");
        }
    }

    #[test]
    fn deepffm_beats_linear_on_interactions() {
        // Interactions dominate: tiny spec has pair terms; DeepFFM/FFM
        // must end ahead of pure LR.
        let run = |arch: Architecture| -> f64 {
            let mut reg = Regressor::new(&tiny_cfg(arch));
            let mut ws = Workspace::new();
            let mut s = SyntheticStream::with_buckets(
                {
                    let mut sp = DatasetSpec::tiny();
                    sp.interaction_scale = 2.5;
                    sp
                },
                33,
                256,
            );
            let mut roll = RollingAuc::new(2000);
            for _ in 0..30_000 {
                let ex = s.next_example();
                let p = reg.learn(&ex, &mut ws);
                roll.add(p, ex.label);
            }
            let late: Vec<f64> = roll.points.iter().rev().take(5).cloned().collect();
            late.iter().sum::<f64>() / late.len() as f64
        };
        let lin = run(Architecture::Linear);
        let ffm = run(Architecture::Ffm);
        assert!(
            ffm > lin + 0.01,
            "ffm {ffm} should beat linear {lin} on interaction data"
        );
    }

    #[test]
    fn context_partial_equals_full_prediction() {
        for arch in [Architecture::Linear, Architecture::Ffm, Architecture::DeepFfm] {
            let mut reg = Regressor::new(&tiny_cfg(arch));
            let mut ws = Workspace::new();
            let mut s = stream();
            // train a bit so weights are non-trivial
            for _ in 0..2000 {
                let ex = s.next_example();
                reg.learn(&ex, &mut ws);
            }
            for _ in 0..100 {
                let ex = s.next_example();
                let full = reg.predict(&ex, &mut ws);
                let c = 2; // first 2 fields are "context"
                let cp = reg.context_partial(&ex.slots[..c]);
                let mut ws2 = Workspace::new();
                let via_cache =
                    reg.predict_with_partial(&cp, &ex.slots[c..], &mut ws2);
                assert!(
                    (full - via_cache).abs() < 1e-5,
                    "{arch:?}: full={full} cached={via_cache}"
                );
            }
        }
    }

    #[test]
    fn full_context_partial_scores_without_candidates() {
        // c == fields, zero candidate fields: every pair comes from the
        // cached partial.  The batched path must score it, not panic
        // (regression: cw == 0 once hit a divide-by-zero in the batch
        // kernel's stride math).
        for arch in [Architecture::Linear, Architecture::Ffm, Architecture::DeepFfm] {
            let reg = Regressor::new(&tiny_cfg(arch));
            let mut ws = Workspace::new();
            let mut s = stream();
            for _ in 0..5 {
                let ex = s.next_example();
                let full = reg.predict(&ex, &mut ws);
                let cp = reg.context_partial(&ex.slots);
                let via = reg.predict_with_partial(&cp, &[], &mut ws);
                assert!((full - via).abs() < 1e-5, "{arch:?}: {full} vs {via}");
            }
        }
    }

    #[test]
    fn capped_scoring_is_chunking_invariant() {
        // The workspace cap must be invisible in the scores: any chunk
        // size — including caps that split the slate unevenly and the
        // degenerate cap 0 — produces bitwise the same output as one
        // uncapped pass, on all three architectures.
        for arch in [Architecture::Linear, Architecture::Ffm, Architecture::DeepFfm] {
            let mut reg = Regressor::new(&tiny_cfg(arch));
            let mut ws = Workspace::new();
            let mut s = stream();
            for _ in 0..500 {
                let ex = s.next_example();
                reg.learn(&ex, &mut ws);
            }
            let c = 2;
            let ctx: Vec<FeatureSlot> = s.next_example().slots[..c].to_vec();
            let cands: Vec<Vec<FeatureSlot>> = (0..11)
                .map(|_| s.next_example().slots[c..].to_vec())
                .collect();
            let cp = reg.context_partial(&ctx);
            let mut full = Vec::new();
            reg.predict_batch_with_partial(&cp, &cands, &mut ws, &mut full);
            for cap in [0usize, 1, 2, 3, 5, 11, 64] {
                let mut got = Vec::new();
                reg.predict_batch_with_partial_capped(&cp, &cands, cap, &mut ws, &mut got);
                assert_eq!(got, full, "{arch:?} cap={cap}");
            }
        }
    }

    #[test]
    fn arch_override_walks_the_ladder() {
        // The degraded-mode hook: a DeepFFM model scored as Ffm drops
        // exactly the neural head (logit = lr + Σ pairs), scored as
        // Linear drops the pairs too (logit = lr, bitwise the hand
        // computation); requesting the model's own arch (or anything
        // above it — clamped) is bit-identical to the plain call.
        let mut reg = Regressor::new(&tiny_cfg(Architecture::DeepFfm));
        let mut ws = Workspace::new();
        let mut s = stream();
        for _ in 0..500 {
            let ex = s.next_example();
            reg.learn(&ex, &mut ws);
        }
        let c = 2;
        let ctx: Vec<FeatureSlot> = s.next_example().slots[..c].to_vec();
        let cands: Vec<Vec<FeatureSlot>> = (0..7)
            .map(|_| s.next_example().slots[c..].to_vec())
            .collect();
        let cp = reg.context_partial(&ctx);
        let score = |arch, ws: &mut Workspace| {
            let mut v = Vec::new();
            reg.predict_batch_with_partial_as(arch, &cp, &cands, ws, &mut v);
            v
        };
        let full = score(Architecture::DeepFfm, &mut ws);
        let mut plain = Vec::new();
        reg.predict_batch_with_partial(&cp, &cands, &mut ws, &mut plain);
        assert_eq!(full, plain, "own-arch override must be bit-neutral");

        let ffm = score(Architecture::Ffm, &mut ws);
        let lin = score(Architecture::Linear, &mut ws);
        assert_ne!(full, ffm, "dropping the nn head must move scores");
        assert_ne!(ffm, lin, "dropping the pairs must move scores");
        // Linear rung == hand-computed LR logit, bitwise (same op order)
        let w = &reg.pool.weights;
        for (cand, &got) in cands.iter().zip(&lin) {
            let mut lr = cp.lr_sum;
            for slot in cand {
                if slot.value != 0.0 {
                    lr += w[reg.layout.lr_idx(slot.bucket)] * slot.value;
                }
            }
            assert_eq!(got, crate::util::math::sigmoid(lr));
        }

        // override above the model's arch clamps: an Ffm model asked
        // for DeepFfm serves plain Ffm (no phantom nn block)
        let mut ffm_reg = Regressor::new(&tiny_cfg(Architecture::Ffm));
        for _ in 0..200 {
            let ex = s.next_example();
            ffm_reg.learn(&ex, &mut ws);
        }
        let cp2 = ffm_reg.context_partial(&ctx);
        let mut asked_up = Vec::new();
        ffm_reg.predict_batch_with_partial_as(
            Architecture::DeepFfm, &cp2, &cands, &mut ws, &mut asked_up,
        );
        let mut own = Vec::new();
        ffm_reg.predict_batch_with_partial(&cp2, &cands, &mut ws, &mut own);
        assert_eq!(asked_up, own);

        // chunking stays invariant per rung
        for arch in [Architecture::Ffm, Architecture::Linear] {
            let want = score(arch, &mut ws);
            for cap in [1usize, 3, 7] {
                let mut got = Vec::new();
                reg.predict_batch_with_partial_capped_as(
                    arch, &cp, &cands, cap, &mut ws, &mut got,
                );
                assert_eq!(got, want, "{arch:?} cap={cap}");
            }
        }
    }

    #[test]
    fn learn_returns_pre_update_prediction() {
        // DeepFFM: return value is the pre-update score.
        let mut reg = Regressor::new(&tiny_cfg(Architecture::DeepFfm));
        let mut ws = Workspace::new();
        let mut s = stream();
        let ex = s.next_example();
        let before = reg.predict(&ex, &mut ws);
        let returned = reg.learn(&ex, &mut ws);
        assert_eq!(before, returned);
        // after the update the prediction must have moved
        let after = reg.predict(&ex, &mut ws);
        assert_ne!(after, before);

        // Linear: a single step strictly moves toward the label (no
        // renormalization effects).
        let mut reg = Regressor::new(&tiny_cfg(Architecture::Linear));
        let ex = s.next_example();
        let before = reg.predict(&ex, &mut ws);
        reg.learn(&ex, &mut ws);
        let after = reg.predict(&ex, &mut ws);
        if ex.label > 0.5 {
            assert!(after >= before);
        } else {
            assert!(after <= before);
        }
    }

    #[test]
    fn importance_weight_scales_update() {
        let cfg = tiny_cfg(Architecture::Linear);
        let mut s = stream();
        let mut ex = s.next_example();
        ex.label = 1.0;
        let delta = |imp: f32| -> f32 {
            let mut reg = Regressor::new(&cfg);
            let mut ws = Workspace::new();
            let mut e2 = ex.clone();
            e2.importance = imp;
            let before = reg.predict(&e2, &mut ws);
            reg.learn(&e2, &mut ws);
            reg.predict(&e2, &mut ws) - before
        };
        assert!(delta(4.0) > delta(1.0));
    }
}
