//! Fleet-wide end-to-end soak (the PR-2 tentpole test):
//!
//!   Hogwild rounds ──► UpdatePipeline ──► route planner (star/tree)
//!   ──► per-DC simulated links (with injected drops) ──► per-replica
//!   delta-chain receivers ──► atomic swaps into 6 live serving
//!   engines — while traffic threads score probes against every
//!   replica concurrently.
//!
//! Per mode (≥3 DCs × ≥2 replicas, ≥5 rounds):
//!   (a) zero torn/mixed-version responses anywhere in the fleet,
//!   (b) after the final catch-up, every replica is bit-identical to
//!       the reference reconstruction,
//!   (c) injected drops leave version skew that the catch-up protocol
//!       (chained-patch replay / full resync) repairs,
//!   (d) the planner's tree route ships strictly fewer inter-DC bytes
//!       than star for the same snapshots.

// Soak/e2e scale: far too slow under the Miri interpreter (~1000x);
// the nightly Miri job covers the scalar kernels and unit props
// instead.
#![cfg(not(miri))]

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::fleet::soak::{run_fleet_soak, FleetSoakConfig};
use fwumious::fleet::{FleetConfig, FleetFabric, LinkSpec, Strategy, Topology};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::transfer::UpdateMode;

fn soak(mode: UpdateMode) -> fwumious::fleet::soak::FleetSoakReport {
    let cfg = FleetSoakConfig::quick(mode);
    assert!(cfg.dcs >= 3 && cfg.replicas_per_dc >= 2 && cfg.rounds >= 5);
    let report = run_fleet_soak(cfg);
    assert!(report.rounds.len() >= 5);
    report.assert_healthy();
    // the injected drops actually happened
    assert!(report.metrics.drops() >= 2, "{mode:?}: fault injection missed");
    assert!(report.metrics.max_version_skew >= 1, "{mode:?}");
    report
}

#[test]
fn fleet_soak_raw_mode() {
    let report = soak(UpdateMode::Raw);
    // full files self-heal: replicas skip ahead, catch-up only fires
    // if the *final* round's shipment was among the lost
    assert_eq!(report.metrics.replays, 0);
}

#[test]
fn fleet_soak_quant_mode() {
    soak(UpdateMode::Quant);
}

#[test]
fn fleet_soak_patch_mode() {
    let report = soak(UpdateMode::PatchOnly);
    assert!(report.metrics.replays + report.metrics.resyncs >= 1);
}

#[test]
fn fleet_soak_quant_patch_mode() {
    let report = soak(UpdateMode::QuantPatch);
    assert!(report.metrics.replays + report.metrics.resyncs >= 1);
    // the production configuration still undercuts raw bills at fleet
    // scale: steady-state updates are far below the raw file
    let steady = report.rounds.last().unwrap();
    assert!(
        steady.update_bytes < steady.raw_bytes / 2,
        "steady-state update {} !< raw {} / 2",
        steady.update_bytes,
        steady.raw_bytes
    );
}

#[test]
fn tree_route_ships_fewer_inter_dc_bytes_than_star() {
    // identical snapshot sequence through both route plans: the
    // fan-out tree must strictly undercut star on the expensive edge
    // for every update mode (and cost nothing when M = 1 per DC)
    let model_cfg = ModelConfig::deep_ffm(4, 2, 1 << 10, &[8]);
    let template = Regressor::new(&model_cfg);
    let mut reg = template.clone();
    let mut ws = Workspace::new();
    let mut stream =
        SyntheticStream::with_buckets(DatasetSpec::tiny(), 77, model_cfg.buckets);
    let mut snaps = Vec::new();
    for _ in 0..3 {
        for _ in 0..600 {
            let ex = stream.next_example();
            reg.learn(&ex, &mut ws);
        }
        snaps.push(reg.clone());
    }

    for mode in UpdateMode::ALL {
        let run = |strategy: Strategy| {
            let topo =
                Topology::uniform(3, 2, LinkSpec::wan(), LinkSpec::lan());
            let mut fc = FleetConfig::new(topo, mode);
            fc.strategy = strategy;
            let mut fab = FleetFabric::new(fc, &template);
            for snap in &snaps {
                fab.publish(snap).unwrap();
            }
            fab.metrics()
        };
        let star = run(Strategy::Star);
        let tree = run(Strategy::Tree);
        assert!(
            tree.inter_bytes() < star.inter_bytes(),
            "{mode:?}: tree {} !< star {}",
            tree.inter_bytes(),
            star.inter_bytes()
        );
        // uniform 2-replica DCs: star crosses the WAN exactly twice as
        // often, and only the tree pays (cheap) intra-DC re-fan-out
        assert_eq!(tree.inter_bytes() * 2, star.inter_bytes(), "{mode:?}");
        assert_eq!(star.intra_bytes(), 0, "{mode:?}");
        assert_eq!(tree.intra_bytes(), tree.inter_bytes(), "{mode:?}");
    }
}

#[test]
fn fleet_soak_star_strategy_also_converges() {
    // route policy must not affect correctness, only the byte bill
    let mut cfg = FleetSoakConfig::quick(UpdateMode::QuantPatch);
    cfg.strategy = Strategy::Star;
    cfg.rounds = 5;
    let report = run_fleet_soak(cfg);
    report.assert_healthy();
    assert_eq!(report.metrics.intra_bytes(), 0);
}
