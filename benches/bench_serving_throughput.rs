//! Headline claim — "more than 300m predictions per second" (fleet-
//! wide, CPU-only).
//!
//! Three measurements:
//!
//! 1. **Batched vs per-candidate scoring** (the request-level batching
//!    tentpole): the same request stream scored candidate-at-a-time
//!    through `predict_with_partial` and request-at-a-time through
//!    `predict_batch_with_partial`.  The batched path amortizes the
//!    prefetch pass, slot assembly and ctx×ctx cache copy across the
//!    fanout and streams MLP weight rows once per 4-candidate register
//!    block.
//! 2. **Cross-request coalescing** (the coalescing tentpole): a
//!    duplicate-context workload — small slates, several requests per
//!    context, the shape context-affinity routing produces — scored
//!    request-at-a-time (one cache lookup + one kernel pass per
//!    REQUEST) vs through `score_requests_coalesced` (one lookup + one
//!    union-slate pass per context GROUP).  Both arms must agree
//!    bitwise; the ratio is the cross-request speedup.
//! 3. **Engine throughput**: the full serving engine (router → batcher
//!    → context cache → coalesced SIMD forward) across worker counts,
//!    with latency p50/p99.
//!
//! Emits machine-readable `BENCH_serving_throughput.json` (candidates/
//! sec for all paths, the batched-vs-sequential and grouped-vs-per-
//! request speedup ratios, per-worker-count engine throughput and
//! latency percentiles) so future PRs can diff regressions.  `--smoke`
//! runs a CI-sized variant.

use fwumious::config::{ModelConfig, ServeConfig};
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::serve::context_cache::ContextCache;
use fwumious::serve::router::Router;
use fwumious::serve::server::{score_requests_coalesced, ServingEngine};
use fwumious::serve::trace::TraceGenerator;
use fwumious::serve::{ModelHandle, Request};
use fwumious::obs::{ObsOptions, ObsRegistry};
use fwumious::simd::{ForcedIsaGuard, IsaLevel};
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj, s};
use fwumious::util::timer::median_time;

const CTX_FIELDS: usize = 6;
const FANOUT: usize = 16;
/// Duplicate-context workload shape: `DUP_GROUP` requests share each
/// context, each carrying a small `DUP_FANOUT`-candidate slate (small
/// slates make the per-request fixed costs — resolve, versioned load,
/// radix lookup, kernel dispatch — the dominant term, which is exactly
/// what coalescing removes).
const DUP_FANOUT: usize = 2;
const DUP_GROUP: usize = 8;
/// Requests per flushed slate handed to the planner (a realistic
/// `max_batch`-sized flush: 8 distinct contexts × DUP_GROUP requests).
const DUP_SLATE_REQS: usize = 8 * DUP_GROUP;

fn trained_model(smoke: bool) -> Regressor {
    let spec = DatasetSpec::criteo_like();
    let buckets = if smoke { 1u32 << 14 } else { 1u32 << 18 };
    let steps = if smoke { 3_000 } else { 50_000 };
    let cfg = ModelConfig::deep_ffm(spec.fields(), 8, buckets, &[32]);
    let mut reg = Regressor::new(&cfg);
    let mut ws = Workspace::new();
    let mut s = SyntheticStream::with_buckets(spec, 41, buckets);
    for _ in 0..steps {
        let ex = s.next_example();
        reg.learn(&ex, &mut ws);
    }
    reg
}

/// Candidate-at-a-time scoring (the pre-batching serving inner loop):
/// one cached partial per request, then one `predict_with_partial` call
/// per candidate.
fn run_sequential(reg: &Regressor, reqs: &[Request]) -> (f64, Vec<f32>) {
    let mut ws = Workspace::new();
    let mut scores = Vec::new();
    let t = std::time::Instant::now();
    for req in reqs {
        let cp = reg.context_partial(&req.context);
        for cand in &req.candidates {
            scores.push(reg.predict_with_partial(&cp, cand, &mut ws));
        }
    }
    (t.elapsed().as_secs_f64(), scores)
}

/// Request-at-a-time scoring through the batched path.
fn run_batched(reg: &Regressor, reqs: &[Request]) -> (f64, Vec<f32>) {
    let mut ws = Workspace::new();
    let mut scores = Vec::new();
    let mut out = Vec::new();
    let t = std::time::Instant::now();
    for req in reqs {
        let cp = reg.context_partial(&req.context);
        reg.predict_batch_with_partial(&cp, &req.candidates, &mut ws, &mut out);
        scores.extend_from_slice(&out);
    }
    (t.elapsed().as_secs_f64(), scores)
}

/// Duplicate-context slates: each slate holds `DUP_SLATE_REQS / dup`
/// distinct contexts, every one shared by `dup` requests with fresh
/// candidate slates, interleaved round-robin (the planner must not
/// depend on group members arriving adjacently).
fn duplicate_context_slates(
    gen: &mut TraceGenerator,
    slates: usize,
    dup: usize,
) -> Vec<Vec<Request>> {
    let groups = DUP_SLATE_REQS / dup;
    (0..slates)
        .map(|_| {
            let donors: Vec<Request> =
                (0..groups).map(|_| gen.next_request("m")).collect();
            let mut slate = Vec::with_capacity(groups * dup);
            for _ in 0..dup {
                for donor in &donors {
                    let mut r = gen.next_request("m");
                    r.context = donor.context.clone();
                    slate.push(r);
                }
            }
            slate
        })
        .collect()
}

/// PR 3's per-request serving inner loop over a flushed slate: resolve
/// + versioned load + ONE cache lookup + ONE kernel pass per request.
fn run_slates_per_request(
    router: &Router,
    cache: &mut ContextCache,
    slates: &[Vec<Request>],
) -> Vec<f32> {
    let mut ws = Workspace::new();
    let mut scores = Vec::new();
    let mut all = Vec::new();
    for slate in slates {
        for req in slate {
            let handle = router.resolve(&req.model).expect("model");
            let (version, model) = handle.load_versioned();
            let cp =
                cache.get_or_compute_named(&model, &req.model, version, &req.context);
            model.predict_batch_with_partial(&cp, &req.candidates, &mut ws, &mut scores);
            all.extend_from_slice(&scores);
        }
    }
    all
}

/// The coalesced path: one `score_requests_coalesced` call per slate
/// (one cache lookup + one union-slate kernel pass per context group).
fn run_slates_coalesced(
    router: &Router,
    cache: &mut ContextCache,
    slates: &[Vec<Request>],
) -> Vec<f32> {
    let mut ws = Workspace::new();
    let mut all = Vec::new();
    for slate in slates {
        let (results, _) =
            score_requests_coalesced(router, cache, &mut ws, 1024, slate);
        for r in results {
            all.extend_from_slice(&r.expect("well-formed request").scores);
        }
    }
    all
}

struct EngineRun {
    preds_per_sec: f64,
    hit_rate: f64,
    coalesce_rate: f64,
    p50_us: f64,
    p99_us: f64,
}

fn run_engine(reg: &Regressor, workers: usize, requests: usize, obs: bool) -> EngineRun {
    let router = Router::new(workers);
    router.register("m", ModelHandle::new(reg.clone()));
    let cfg = ServeConfig {
        workers,
        max_batch: 256,
        max_wait_us: 200,
        context_cache_entries: 65_536,
        max_group_candidates: 1024,
        ..ServeConfig::default()
    };
    let engine = if obs {
        // registry attached, tracing off — the production scrape shape
        let registry = std::sync::Arc::new(ObsRegistry::new());
        ServingEngine::start_with_obs(router, cfg, ObsOptions::with_registry(registry))
    } else {
        ServingEngine::start(router, cfg)
    };
    let fields = reg.cfg.fields;
    let mut gen = TraceGenerator::new(17, fields, CTX_FIELDS, reg.cfg.buckets, FANOUT);
    let reqs = gen.take(requests, "m");
    let t = std::time::Instant::now();
    let mut pending = Vec::with_capacity(1024);
    for (i, req) in reqs.into_iter().enumerate() {
        pending.push(engine.submit(req).expect("submit"));
        if pending.len() >= 1024 || i + 1 == requests {
            for rx in pending.drain(..) {
                rx.recv().unwrap().expect("score");
            }
        }
    }
    let secs = t.elapsed().as_secs_f64();
    let stats = engine.shutdown();
    assert_eq!(stats.errors, 0);
    let hist = stats.latency.as_ref().expect("latency histogram");
    EngineRun {
        preds_per_sec: stats.candidates as f64 / secs,
        hit_rate: stats.cache_hit_rate(),
        coalesce_rate: stats.coalesced_requests as f64
            / stats.requests.max(1) as f64,
        p50_us: hist.quantile_ns(0.5) / 1e3,
        p99_us: hist.quantile_ns(0.99) / 1e3,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let direct_requests = if smoke { 300 } else { 2_000 };
    println!(
        "== Headline: candidate-scoring throughput (SIMD {}{}) ==\n",
        fwumious::simd::isa_name(),
        if smoke { ", smoke" } else { "" }
    );
    let reg = trained_model(smoke);
    println!(
        "model: DeepFFM {} fields ({} context), K={}, hidden {:?}, {:.0} MB weights, fanout {}",
        reg.cfg.fields,
        CTX_FIELDS,
        reg.cfg.latent_dim,
        reg.cfg.hidden,
        reg.num_weights() as f64 * 4.0 / 1e6,
        FANOUT
    );

    // -- batched vs per-candidate, single thread, identical requests
    let mut gen =
        TraceGenerator::new(29, reg.cfg.fields, CTX_FIELDS, reg.cfg.buckets, FANOUT);
    let reqs = gen.take(direct_requests, "m");
    // warm-up pass (page in the weight table, size the workspaces)
    let _ = run_batched(&reg, &reqs[..reqs.len().min(32)]);
    let _ = run_sequential(&reg, &reqs[..reqs.len().min(32)]);
    let (seq_secs, seq_scores) = run_sequential(&reg, &reqs);
    let (bat_secs, bat_scores) = run_batched(&reg, &reqs);
    assert_eq!(seq_scores.len(), bat_scores.len());
    for (i, (a, b)) in bat_scores.iter().zip(&seq_scores).enumerate() {
        assert!(
            (a - b).abs() < 1e-5,
            "candidate {i}: batched {a} vs sequential {b}"
        );
    }
    let n_cands = (direct_requests * FANOUT) as f64;
    let seq_cps = n_cands / seq_secs;
    let bat_cps = n_cands / bat_secs;
    let speedup = bat_cps / seq_cps;
    println!("\n-- single-thread scoring path (B = {FANOUT} candidates/request) --");
    println!("{:>16} {:>14}", "path", "cands/s");
    println!("{:>16} {:>14.0}", "per-candidate", seq_cps);
    println!("{:>16} {:>14.0}", "batched", bat_cps);
    println!("batched-vs-sequential speedup: {speedup:.2}x");

    // -- the same batched path pinned to each ISA rung (the ladder's
    // end-to-end effect on serving, not just the kernels)
    let rungs = fwumious::simd::available_levels();
    let rung_reps = if smoke { 3 } else { 5 };
    println!("\n-- per-rung batched scoring (K={}) --", reg.cfg.latent_dim);
    println!("{:>12} {:>14} {:>10}", "rung", "cands/s", "vs scalar");
    let mut rung_rows = Vec::new();
    let mut scalar_rung_cps = f64::NAN;
    for &lvl in &rungs {
        // RAII forcing: restored (to unforced) when the arm ends
        let _guard = ForcedIsaGuard::force(lvl);
        // best-of-N: the arm is short and the ratio is what matters
        let mut secs = f64::INFINITY;
        for _ in 0..rung_reps {
            secs = secs.min(run_batched(&reg, &reqs).0);
        }
        let cps = n_cands / secs;
        if lvl == IsaLevel::Scalar {
            scalar_rung_cps = cps;
        }
        println!(
            "{:>12} {:>14.0} {:>9.2}x",
            lvl.name(),
            cps,
            cps / scalar_rung_cps
        );
        rung_rows.push(obj(vec![
            ("isa_rung", s(lvl.name())),
            ("k", num(reg.cfg.latent_dim as f64)),
            ("cands_per_sec", num(cps)),
            ("speedup_vs_scalar", num(cps / scalar_rung_cps)),
        ]));
    }

    // -- cross-request coalescing on a duplicate-context workload
    let dup_slates_n = if smoke { 30 } else { 200 };
    let mut dup_gen =
        TraceGenerator::new(31, reg.cfg.fields, CTX_FIELDS, reg.cfg.buckets, DUP_FANOUT);
    let slates = duplicate_context_slates(&mut dup_gen, dup_slates_n, DUP_GROUP);
    let dup_reqs = dup_slates_n * DUP_SLATE_REQS;
    let dup_cands = (dup_reqs * DUP_FANOUT) as f64;
    let router = Router::new(1);
    router.register("m", ModelHandle::new(reg.clone()));
    let mut cache = ContextCache::new(65_536);
    // warm the cache + page weights, and pin the bit-contract: grouped
    // scoring must equal the per-request path exactly
    let per_request_scores = run_slates_per_request(&router, &mut cache, &slates);
    let grouped_scores = run_slates_coalesced(&router, &mut cache, &slates);
    assert_eq!(per_request_scores.len(), grouped_scores.len());
    for (i, (a, b)) in grouped_scores.iter().zip(&per_request_scores).enumerate() {
        assert_eq!(
            a, b,
            "candidate {i}: grouped {a} vs per-request {b} — the coalesced \
             path must be bit-identical"
        );
    }
    let reps = if smoke { 3 } else { 5 };
    let xreq_secs = median_time(1, reps, || run_slates_per_request(&router, &mut cache, &slates));
    let grp_secs = median_time(1, reps, || run_slates_coalesced(&router, &mut cache, &slates));
    let xreq_cps = dup_cands / xreq_secs;
    let grp_cps = dup_cands / grp_secs;
    let xreq_speedup = grp_cps / xreq_cps;
    println!(
        "\n-- cross-request coalescing ({DUP_GROUP} requests/context, \
         {DUP_FANOUT} candidates/request, {DUP_SLATE_REQS}-request slates) --"
    );
    println!("{:>16} {:>14}", "path", "cands/s");
    println!("{:>16} {:>14.0}", "per-request", xreq_cps);
    println!("{:>16} {:>14.0}", "grouped", grp_cps);
    println!("grouped-vs-per-request speedup: {xreq_speedup:.2}x (bit-identical scores)");

    // -- full engine across worker counts
    let max_workers = std::thread::available_parallelism()
        .map(|n| n.get().min(if smoke { 2 } else { 16 }))
        .unwrap_or(if smoke { 2 } else { 8 });
    println!(
        "\n{:>8} {:>14} {:>16} {:>8} {:>10} {:>10}",
        "workers", "preds/s", "preds/s/core", "hit%", "p50 us", "p99 us"
    );
    let mut per_core_best = 0f64;
    let mut engine_rows = Vec::new();
    let mut w = 1;
    while w <= max_workers {
        let requests = if smoke { 1_500 * w } else { 6_000 * w };
        let run = run_engine(&reg, w, requests, false);
        per_core_best = per_core_best.max(run.preds_per_sec / w as f64);
        println!(
            "{:>8} {:>14.0} {:>16.0} {:>7.1}% {:>10.1} {:>10.1}",
            w,
            run.preds_per_sec,
            run.preds_per_sec / w as f64,
            run.hit_rate * 100.0,
            run.p50_us,
            run.p99_us
        );
        engine_rows.push(obj(vec![
            ("workers", num(w as f64)),
            ("preds_per_sec", num(run.preds_per_sec)),
            ("preds_per_sec_per_core", num(run.preds_per_sec / w as f64)),
            ("cache_hit_rate", num(run.hit_rate)),
            ("coalesce_rate", num(run.coalesce_rate)),
            ("latency_p50_us", num(run.p50_us)),
            ("latency_p99_us", num(run.p99_us)),
        ]));
        w *= 2;
    }

    // -- observability overhead: the same engine with a metrics
    // registry attached (spans recorded, tracing off) vs the default
    // private-registry path; best-of-N to cut scheduler noise
    let ow = max_workers.min(2);
    let oreq = if smoke { 1_500 * ow } else { 6_000 * ow };
    let obs_reps = if smoke { 1 } else { 3 };
    let mut base_best = 0f64;
    let mut obs_best = 0f64;
    for _ in 0..obs_reps {
        base_best = base_best.max(run_engine(&reg, ow, oreq, false).preds_per_sec);
        obs_best = obs_best.max(run_engine(&reg, ow, oreq, true).preds_per_sec);
    }
    let obs_ratio = obs_best / base_best;
    println!(
        "\n-- observability overhead ({ow} workers): default {base_best:.0} \
         vs registry-attached {obs_best:.0} preds/s ({obs_ratio:.3}x)"
    );

    let path = bench_env::write_report(
        "serving_throughput",
        smoke,
        vec![
            ("fields", num(reg.cfg.fields as f64)),
            ("context_fields", num(CTX_FIELDS as f64)),
            ("latent_dim", num(reg.cfg.latent_dim as f64)),
            ("fanout", num(FANOUT as f64)),
            ("sequential_cands_per_sec", num(seq_cps)),
            ("batched_cands_per_sec", num(bat_cps)),
            ("speedup_batched_vs_sequential", num(speedup)),
            ("scoring_rungs", arr(rung_rows)),
            ("dup_fanout", num(DUP_FANOUT as f64)),
            ("dup_group_size", num(DUP_GROUP as f64)),
            ("dup_requests", num(dup_reqs as f64)),
            ("per_request_cands_per_sec", num(xreq_cps)),
            ("grouped_cands_per_sec", num(grp_cps)),
            ("speedup_grouped_vs_per_request", num(xreq_speedup)),
            ("engine", arr(engine_rows)),
            ("per_core_best_preds_per_sec", num(per_core_best)),
            ("cores_for_300m", num(300e6 / per_core_best)),
            ("obs_preds_per_sec", num(obs_best)),
            ("obs_throughput_ratio", num(obs_ratio)),
        ],
    );
    println!(
        "\n→ 300M preds/s needs ≈{:.0} cores at the measured per-core rate;",
        300e6 / per_core_best
    );
    println!("  the paper's multi-DC fleet (hundreds of servers × tens of cores) clears that.");
    println!("report -> {path}");
    // The documented guarantee (README / verify skill): batched beats
    // per-candidate by ≥ 1.5x at this fanout.  Only enforceable where
    // the SIMD kernels are live — on scalar-dispatch hosts both arms
    // run identical arithmetic and only call overhead is saved.
    // Asserted after the report write so a regression still leaves the
    // numbers on disk.
    if fwumious::simd::simd_active() {
        assert!(
            speedup >= 1.5,
            "batched path speedup {speedup:.2}x below the 1.5x floor \
             ({bat_cps:.0} vs {seq_cps:.0} cands/s)"
        );
        // Cross-request floor: on the duplicate-context workload the
        // coalesced path must clear 1.2x over per-request scoring.
        assert!(
            xreq_speedup >= 1.2,
            "cross-request speedup {xreq_speedup:.2}x below the 1.2x floor \
             ({grp_cps:.0} vs {xreq_cps:.0} cands/s)"
        );
        // Observability floor: a registry-attached engine (tracing
        // off) must keep ≥ 95% of default throughput.  Smoke runs are
        // too short to measure this without flaking.
        if !smoke {
            assert!(
                obs_ratio >= 0.95,
                "registry-attached engine at {obs_ratio:.3}x of default \
                 throughput, below the 0.95x floor \
                 ({obs_best:.0} vs {base_best:.0} preds/s)"
            );
        }
    } else {
        println!("(scalar dispatch host: 1.5x / 1.2x / 0.95x floors not enforced)");
    }
}
