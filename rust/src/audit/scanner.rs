//! The line-oriented scanner behind `fw audit`.
//!
//! Deliberately not a parser: the invariants it enforces are lexical
//! (a rationale comment adjacent to a site, a banned method name, a
//! banned type in a signature), and a line scanner that strips string
//! literals and comments first is both fast and predictable enough to
//! run on every CI push.  The contiguous comment/attribute *block walk*
//! is the one piece of real machinery: a marker comment may sit any
//! number of comment lines above its site, and one rationale may cover
//! a run of consecutive sites (e.g. five Relaxed counter bumps under a
//! single `// ordering:` block).

use super::{Finding, Rule};

/// Paths (relative to the repo root, `/`-separated) whose non-test code
/// must not call `.unwrap()` / `.expect(` — the serving, fleet, deploy
/// and SIMD planes plus the Hogwild training loop, where a panic takes
/// down a worker thread and, through it, live traffic.
const HOT_PATHS: [&str; 5] = [
    "rust/src/serve/",
    "rust/src/fleet/",
    "rust/src/deploy/",
    "rust/src/simd/",
    "rust/src/train/hogwild.rs",
];

/// Replace string and char literals with empty equivalents so their
/// contents can't trigger (or mask) a rule.  Line-local and heuristic:
/// raw strings and multi-line literals are out of scope — the repo
/// style keeps rule-relevant code out of such literals.
fn strip_strings(line: &str) -> String {
    let chars: Vec<char> = line.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(n);
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '"' {
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            out.push_str("\"\"");
            continue;
        }
        if c == '\'' && i + 2 < n && (chars[i + 2] == '\'' || chars[i + 1] == '\\') {
            if let Some(off) = chars[i + 1..].iter().position(|&d| d == '\'') {
                i += off + 2;
                out.push_str("''");
                continue;
            }
        }
        out.push(c);
        i += 1;
    }
    out
}

/// Drop a trailing `//` comment (after string stripping, so a `//`
/// inside a literal doesn't truncate the code).
fn strip_comment(line: &str) -> &str {
    match line.find("//") {
        Some(idx) => &line[..idx],
        None => line,
    }
}

fn is_word_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Whether `hay` contains `word` delimited by non-word characters.
fn has_word(hay: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = hay[start..].find(word) {
        let at = start + pos;
        let before_ok = hay[..at].chars().next_back().is_none_or(|c| !is_word_char(c));
        let after_ok = hay[at + word.len()..]
            .chars()
            .next()
            .is_none_or(|c| !is_word_char(c));
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

/// Whether the line declares a function: the word `fn` followed by
/// whitespace and an identifier character.
fn starts_fn_decl(code: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find("fn") {
        let at = start + pos;
        let before_ok = code[..at].chars().next_back().is_none_or(|c| !is_word_char(c));
        let rest = &code[at + 2..];
        let mut it = rest.chars();
        if before_ok {
            if let Some(c) = it.next() {
                if c.is_whitespace() {
                    let after_ws = rest.trim_start();
                    if after_ws.chars().next().is_some_and(is_word_char) {
                        return true;
                    }
                }
            }
        }
        start = at + 2;
    }
    false
}

/// Whether the (single-line) start of a signature declares a `pub` /
/// `pub(crate)` fn, optionally `unsafe`.
fn is_pub_fn(code: &str) -> bool {
    let norm: String = code.split_whitespace().collect::<Vec<_>>().join(" ");
    for pat in [
        "pub fn ",
        "pub unsafe fn ",
        "pub(crate) fn ",
        "pub(crate) unsafe fn ",
        "pub (crate) fn ",
        "pub (crate) unsafe fn ",
    ] {
        if let Some(at) = norm.find(pat) {
            if norm[..at].chars().next_back().is_none_or(|c| !is_word_char(c)) {
                return true;
            }
        }
    }
    false
}

/// Whether an accumulated signature returns `Result<_, String>`:
/// whitespace-insensitively, `-> Result<` followed (anywhere in the
/// type) by `, String>`.
fn returns_string_result(sig: &str) -> bool {
    let norm: String = sig.chars().filter(|c| !c.is_whitespace()).collect();
    match norm.find("->Result<") {
        Some(at) => norm[at..].contains(",String>"),
        None => false,
    }
}

/// Walk the contiguous comment/attribute block immediately above line
/// `ln` (1-based), returning true if any line of the block — or the
/// site line itself — contains `marker`.  When `run` is given, lines
/// containing it are also stepped over, so one rationale block covers a
/// run of consecutive sites.
fn block_has(lines: &[&str], ln: usize, marker: &str, run: Option<&str>) -> bool {
    if lines[ln - 1].contains(marker) {
        return true;
    }
    let mut j = ln as isize - 2;
    while j >= 0 {
        let raw = lines[j as usize];
        let prev = raw.trim_start();
        if prev.starts_with("//") || prev.starts_with("#[") {
            if raw.contains(marker) {
                return true;
            }
            j -= 1;
        } else if run.is_some_and(|r| raw.contains(r)) {
            j -= 1;
        } else {
            break;
        }
    }
    false
}

/// Scan one source file.  `relpath` is the repo-root-relative path with
/// `/` separators (it selects the hot-path rule and labels findings).
pub fn scan_source(relpath: &str, text: &str) -> Vec<Finding> {
    let lines: Vec<&str> = text.split('\n').collect();
    let hot = HOT_PATHS
        .iter()
        .any(|h| relpath.starts_with(h) || relpath == h.trim_end_matches('/'));

    let mut findings = Vec::new();
    // cfg(test) region tracking via brace depth: the attribute arms the
    // tracker, the next `{` opens the region, and the region ends when
    // depth returns to its pre-region level.
    let mut in_test = false;
    let mut test_depth = 0i64;
    let mut depth = 0i64;
    let mut pending_test = false;
    // pub-fn signature accumulation across wrapped lines.
    let mut sig: Option<String> = None;
    let mut sig_pub = false;
    let mut sig_line = 0usize;

    let mut finding = |rule: Rule, ln: usize, raw: &str| {
        findings.push(Finding {
            rule,
            path: relpath.to_string(),
            line: ln,
            snippet: raw.trim().chars().take(90).collect(),
        });
    };

    for (idx, &raw) in lines.iter().enumerate() {
        let ln = idx + 1;
        let stripped = strip_strings(raw);
        let code = strip_comment(&stripped);
        if !in_test && (raw.contains("#[cfg(test)]") || raw.contains("#[cfg(all(test")) {
            pending_test = true;
        }
        let opens = code.matches('{').count() as i64;
        let closes = code.matches('}').count() as i64;
        if pending_test && opens > 0 {
            in_test = true;
            test_depth = depth;
            pending_test = false;
        }
        depth += opens - closes;
        if in_test && depth <= test_depth {
            in_test = false;
        }

        let comment_only = raw.trim_start().starts_with("//");

        // -- rule: safety-comment -------------------------------------
        if !comment_only
            && has_word(code, "unsafe")
            && !block_has(&lines, ln, "SAFETY", None)
            && !block_has(&lines, ln, "# Safety", None)
        {
            finding(Rule::SafetyComment, ln, raw);
        }

        // -- rule: ordering-rationale (non-test code only) ------------
        if !comment_only
            && !in_test
            && code.contains("Ordering::")
            && !block_has(&lines, ln, "ordering:", Some("Ordering::"))
        {
            finding(Rule::OrderingRationale, ln, raw);
        }

        // -- rule: hot-path-unwrap ------------------------------------
        if hot
            && !in_test
            && !comment_only
            && (code.contains(".unwrap()") || code.contains(".expect("))
        {
            finding(Rule::HotPathUnwrap, ln, raw);
        }

        // -- rule: string-error (pub fn ... -> Result<_, String>) -----
        if !comment_only {
            if sig.is_none() {
                if starts_fn_decl(code) {
                    sig = Some(code.to_string());
                    sig_pub = is_pub_fn(code);
                    sig_line = ln;
                }
            } else if let Some(s) = sig.as_mut() {
                s.push(' ');
                s.push_str(code);
            }
            // the signature ends at the body brace or a trait-decl `;`
            if sig.is_some() && (code.contains('{') || code.contains(';')) {
                if let Some(s) = sig.take() {
                    if sig_pub && returns_string_result(&s) {
                        finding(Rule::StringError, sig_line, lines[sig_line - 1]);
                    }
                }
            }
        }
    }
    findings
}

/// The bench-env rule: every bench source must route its results
/// through `util/bench_env.rs` (machine-context emission), detected
/// lexically by a `bench_env` reference.
pub fn scan_bench_env(relpath: &str, text: &str) -> Option<Finding> {
    if text.contains("bench_env") {
        None
    } else {
        Some(Finding {
            rule: Rule::BenchEnv,
            path: relpath.to_string(),
            line: 1,
            snippet: "bench does not emit through util/bench_env.rs".to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn string_and_char_literals_are_stripped() {
        assert_eq!(strip_strings("let s = \"unsafe {\";"), r#"let s = "";"#);
        assert_eq!(strip_strings(r#"let c = '"'; x"#), "let c = ''; x");
        assert_eq!(strip_strings(r#"let e = "a\"b";"#), r#"let e = "";"#);
    }

    #[test]
    fn comment_stripping_respects_strings() {
        let s = strip_strings(r#"let u = "https://x"; // tail"#);
        assert_eq!(strip_comment(&s), r#"let u = ""; "#);
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("return unsafe { f() }", "unsafe"));
        assert!(!has_word("let has_word_unsafe_x = 1", "unsafe"));
        assert!(starts_fn_decl("pub fn foo("));
        assert!(starts_fn_decl("    unsafe fn bar<T>("));
        assert!(!starts_fn_decl("let fnord = 1;"));
        assert!(is_pub_fn("pub fn x("));
        assert!(is_pub_fn("pub(crate) unsafe fn x("));
        assert!(!is_pub_fn("fn x("));
    }

    #[test]
    fn string_result_detection_spans_lines() {
        assert!(returns_string_result("pub fn f() -> Result<u32, String>"));
        assert!(returns_string_result("pub fn f( ) ->   Result< Vec<u8> , String >"));
        assert!(!returns_string_result("pub fn f() -> Result<String, Error>"));
        assert!(!returns_string_result("pub fn f() -> Option<String>"));
    }
}
