//! End-to-end production-loop integration test:
//!
//!   online train → snapshot → quantize → patch → ship over simulated
//!   channel → apply at the serving DC → hot-swap → serve
//!
//! asserting (a) reconstruction fidelity, (b) Table-4-shaped bandwidth
//! savings, (c) the swapped model actually serves the new weights.

// Soak/e2e scale: far too slow under the Miri interpreter (~1000x);
// the nightly Miri job covers the scalar kernels and unit props
// instead.
#![cfg(not(miri))]

use std::sync::Arc;

use fwumious::config::{ModelConfig, ServeConfig};
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::serve::router::Router;
use fwumious::serve::server::ServingEngine;
use fwumious::serve::trace::TraceGenerator;
use fwumious::serve::ModelHandle;
use fwumious::transfer::{
    SimulatedChannel, UpdateMode, UpdatePipeline, UpdateReceiver,
};

#[test]
fn online_rounds_through_quant_patch_channel_to_serving() {
    // Production regime: the hashed weight space is much larger than
    // the per-round update footprint (the paper's 5-minute windows
    // touch a small fraction of a multi-GB model).
    let buckets = 1u32 << 15;
    let cfg = ModelConfig::deep_ffm(6, 2, buckets, &[8]);
    let mut trainer_reg = Regressor::new(&cfg);
    let mut ws = Workspace::new();
    let mut stream =
        SyntheticStream::with_buckets(DatasetSpec::tiny(), 77, buckets);
    // tiny spec has 4 fields; rebuild a 6-field-compatible stream by
    // using criteo-like shrunk spec instead
    let mut spec = DatasetSpec::tiny();
    spec.cat_fields = 5; // 1 cont + 5 cat = 6 fields
    stream = SyntheticStream::with_buckets(spec, 77, buckets);

    // serving side
    let handle = ModelHandle::new(trainer_reg.clone());
    let router = Router::new(2);
    router.register("ctr", handle.clone());
    let engine = ServingEngine::start(
        router,
        ServeConfig {
            workers: 2,
            max_batch: 64,
            max_wait_us: 100,
            context_cache_entries: 1024,
            max_group_candidates: 1024,
            ..ServeConfig::default()
        },
    );

    // transfer plane
    let mut pipe = UpdatePipeline::new(UpdateMode::QuantPatch);
    let mut recv = UpdateReceiver::new(UpdateMode::QuantPatch);
    recv.set_template(trainer_reg.clone());
    let mut channel = SimulatedChannel::with_bandwidth(10_000_000.0, 0.01);
    let mut raw_channel = SimulatedChannel::with_bandwidth(10_000_000.0, 0.01);

    let mut gen = TraceGenerator::new(5, 6, 3, buckets, 4);
    let mut update_sizes = Vec::new();

    for round in 0..4 {
        // 1. online training round (small relative to the weight space)
        for _ in 0..1000 {
            let ex = stream.next_example();
            trainer_reg.learn(&ex, &mut ws);
        }
        // 2. encode + ship
        let update = pipe.encode(&trainer_reg);
        update_sizes.push(update.bytes.len());
        channel.ship(&update);
        raw_channel.ship(&fwumious::transfer::WireUpdate {
            mode: UpdateMode::Raw,
            bytes: fwumious::model::io::to_bytes(&trainer_reg, false),
            encode_seconds: 0.0,
        });
        // 3. receive + reconstruct + hot-swap
        let reconstructed = recv.apply(&update).unwrap();
        let max_err = reconstructed
            .pool
            .weights
            .iter()
            .zip(&trainer_reg.pool.weights)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "round {round}: reconstruction err {max_err}");
        handle.swap(reconstructed);

        // 4. serve against the fresh weights
        let req = gen.next_request("ctr");
        let resp = engine.score(req.clone()).unwrap();
        assert_eq!(resp.scores.len(), 4);
        // serving scores match the reconstructed-model scores (within
        // quantization error translated through sigmoid)
        let current = handle.load();
        let mut ws2 = Workspace::new();
        let cp = current.context_partial(&req.context);
        for (i, cand) in req.candidates.iter().enumerate() {
            let direct = current.predict_with_partial(&cp, cand, &mut ws2);
            assert!((direct - resp.scores[i]).abs() < 1e-6);
        }
    }

    // Table-4 shape: steady-state quant+patch updates are far smaller
    // than raw weight files.
    let steady = *update_sizes.last().unwrap();
    let raw_per_round = raw_channel.total_bytes / raw_channel.messages;
    assert!(
        (steady as u64) < raw_per_round / 4,
        "quant+patch {steady} bytes !≪ raw {raw_per_round} bytes"
    );
    // bandwidth ledger consistency
    assert_eq!(channel.messages, 4);
    assert!(channel.total_bytes < raw_channel.total_bytes);

    let stats = engine.shutdown();
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.requests, 4);
}

#[test]
fn all_update_modes_converge_to_same_serving_behaviour() {
    let buckets = 1u32 << 10;
    let cfg = ModelConfig::ffm(4, 2, buckets);
    // train one model
    let mut reg = Regressor::new(&cfg);
    let mut ws = Workspace::new();
    let mut stream = SyntheticStream::with_buckets(DatasetSpec::tiny(), 9, buckets);
    for _ in 0..3000 {
        let ex = stream.next_example();
        reg.learn(&ex, &mut ws);
    }
    // ship through each mode; all reconstructions must agree within
    // quantization tolerance
    let test: Vec<_> = (0..300).map(|_| stream.next_example()).collect();
    let mut baseline: Option<Vec<f32>> = None;
    for mode in UpdateMode::ALL {
        let mut pipe = UpdatePipeline::new(mode);
        let mut recv = UpdateReceiver::new(mode);
        recv.set_template(Regressor::new(&cfg));
        let got = recv.apply(&pipe.encode(&reg)).unwrap();
        let scores: Vec<f32> = test
            .iter()
            .map(|ex| got.predict(ex, &mut ws))
            .collect();
        match &baseline {
            None => baseline = Some(scores),
            Some(base) => {
                for (a, b) in base.iter().zip(&scores) {
                    assert!((a - b).abs() < 5e-3, "{mode:?}: {a} vs {b}");
                }
            }
        }
    }
}

#[test]
fn hogwild_then_transfer_then_serve() {
    use fwumious::train::hogwild::{train_chunk, HogwildConfig};
    let buckets = 1u32 << 10;
    let cfg = ModelConfig::deep_ffm(4, 2, buckets, &[8]);
    let mut reg = Regressor::new(&cfg);
    let mut stream = SyntheticStream::with_buckets(DatasetSpec::tiny(), 11, buckets);
    let chunk = stream.take_examples(10_000);
    let stats = train_chunk(&mut reg, &chunk, HogwildConfig { threads: 4 }, 2000);
    assert_eq!(stats.examples, 10_000);

    let mut pipe = UpdatePipeline::new(UpdateMode::PatchOnly);
    let mut recv = UpdateReceiver::new(UpdateMode::PatchOnly);
    let served = recv.apply(&pipe.encode(&reg)).unwrap();
    assert_eq!(served.pool.weights, reg.pool.weights);

    let handle = ModelHandle::new(served);
    let router = Router::new(1);
    router.register("m", handle);
    let engine = ServingEngine::start(router, ServeConfig::default());
    let mut gen = TraceGenerator::new(3, 4, 2, buckets, 8);
    for _ in 0..50 {
        let req = gen.next_request("m");
        let resp = engine.score(req).unwrap();
        assert!(resp.scores.iter().all(|s| s.is_finite()));
    }
    assert_eq!(engine.shutdown().errors, 0);
}
