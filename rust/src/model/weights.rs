//! Flat weight pool and its byte-stable layout.
//!
//! All model parameters live in one contiguous `Vec<f32>` with a fixed,
//! deterministic layout derived from the [`ModelConfig`].  That
//! "consistent memory-level structure of weight files" (§6) is what
//! makes the byte-level patcher work: two training rounds of the same
//! config produce files whose differing bytes are exactly the weights
//! that moved.
//!
//! Optimizer (AdaGrad accumulator) state lives in a *separate* pool of
//! the same geometry — "the latter are not required for actual
//! inference, which immediately reduces the required space by half."

use crate::config::{Architecture, ModelConfig};
use crate::util::rng::Pcg32;

/// Offsets of one dense layer inside the MLP section.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerLayout {
    /// Input width.
    pub rows: usize,
    /// Output width.
    pub cols: usize,
    /// Pool offset of the row-major weight matrix `[rows * cols]`.
    pub w_off: usize,
    /// Pool offset of the bias `[cols]`.
    pub b_off: usize,
}

/// Pool offsets for every section.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Layout {
    /// LR table offset (always 0) and length (`buckets`).
    pub lr_off: usize,
    pub lr_len: usize,
    /// FFM table offset; length `buckets * fields * latent_dim`.
    pub ffm_off: usize,
    pub ffm_len: usize,
    /// Hidden layers.
    pub layers: Vec<LayerLayout>,
    /// Output head: weight vector offset/len and bias offset.
    pub w_out_off: usize,
    pub w_out_len: usize,
    pub b_out_off: usize,
    /// Total pool length.
    pub total: usize,
}

impl Layout {
    pub fn new(cfg: &ModelConfig) -> Self {
        let lr_off = 0;
        let lr_len = cfg.buckets as usize;
        let ffm_off = lr_off + lr_len;
        let ffm_len = match cfg.arch {
            Architecture::Linear => 0,
            _ => cfg.buckets as usize * cfg.fields * cfg.latent_dim,
        };
        let mut cursor = ffm_off + ffm_len;
        let mut layers = Vec::new();
        let mut w_out_off = cursor;
        let mut w_out_len = 0;
        let mut b_out_off = cursor;
        if cfg.arch == Architecture::DeepFfm {
            let mut prev = cfg.merged_dim();
            for &h in &cfg.hidden {
                let w_off = cursor;
                cursor += prev * h;
                let b_off = cursor;
                cursor += h;
                layers.push(LayerLayout { rows: prev, cols: h, w_off, b_off });
                prev = h;
            }
            w_out_off = cursor;
            w_out_len = prev;
            cursor += prev;
            b_out_off = cursor;
            cursor += 1;
        }
        Layout {
            lr_off,
            lr_len,
            ffm_off,
            ffm_len,
            layers,
            w_out_off,
            w_out_len,
            b_out_off,
            total: cursor,
        }
    }

    /// Global pool index of the LR weight for `bucket`.
    #[inline]
    pub fn lr_idx(&self, bucket: u32) -> usize {
        self.lr_off + bucket as usize
    }

    /// Global pool index of latent element `(bucket, toward_field, k)`.
    #[inline]
    pub fn ffm_idx(&self, bucket: u32, fields: usize, k: usize, toward: usize, kk: usize) -> usize {
        self.ffm_off + bucket as usize * fields * k + toward * k + kk
    }
}

/// The weight pool: inference weights plus (optional) optimizer state.
#[derive(Clone, Debug)]
pub struct WeightPool {
    pub weights: Vec<f32>,
    /// AdaGrad accumulators, same geometry as `weights`; empty for
    /// inference-only pools.
    pub acc: Vec<f32>,
}

impl WeightPool {
    /// Allocate and initialize per the config's seed.
    pub fn init(cfg: &ModelConfig, layout: &Layout) -> Self {
        let mut w = vec![0f32; layout.total];
        let mut rng = Pcg32::new(cfg.seed, 0x3133_7);
        // LR weights start at zero (VW convention).
        // FFM latents: U(-init_ffm, init_ffm).
        for v in &mut w[layout.ffm_off..layout.ffm_off + layout.ffm_len] {
            *v = rng.range_f32(-cfg.init_ffm, cfg.init_ffm);
        }
        // MLP: uniform He-style init, biases zero.
        for l in &layout.layers {
            let span = (6.0 / l.rows as f32).sqrt();
            for i in 0..l.rows * l.cols {
                w[l.w_off + i] = rng.range_f32(-span, span);
            }
        }
        if layout.w_out_len > 0 {
            let span = (1.0 / layout.w_out_len as f32).sqrt();
            for i in 0..layout.w_out_len {
                w[layout.w_out_off + i] = rng.range_f32(-span, span);
            }
        }
        // AdaGrad accumulators start at 1.0: the first update is then
        // exactly lr * g and the step size decays from there.
        let acc = vec![1f32; layout.total];
        WeightPool { weights: w, acc }
    }

    /// Strip optimizer state (inference deployment).
    pub fn to_inference(&self) -> WeightPool {
        WeightPool { weights: self.weights.clone(), acc: Vec::new() }
    }

    pub fn has_optimizer_state(&self) -> bool {
        !self.acc.is_empty()
    }

    /// Bytes of the inference weights (used by Table 4 size accounting).
    pub fn inference_bytes(&self) -> usize {
        self.weights.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn layout_deepffm_sections_contiguous() {
        let cfg = ModelConfig::deep_ffm(4, 2, 64, &[8, 4]);
        let l = Layout::new(&cfg);
        assert_eq!(l.lr_off, 0);
        assert_eq!(l.lr_len, 64);
        assert_eq!(l.ffm_off, 64);
        assert_eq!(l.ffm_len, 64 * 4 * 2);
        let d = cfg.merged_dim(); // 1 + 6 = 7
        assert_eq!(l.layers.len(), 2);
        assert_eq!(l.layers[0].rows, d);
        assert_eq!(l.layers[0].cols, 8);
        assert_eq!(l.layers[0].w_off, 64 + 512);
        assert_eq!(l.layers[0].b_off, 64 + 512 + d * 8);
        assert_eq!(l.layers[1].rows, 8);
        assert_eq!(l.layers[1].cols, 4);
        assert_eq!(l.w_out_len, 4);
        assert_eq!(l.b_out_off + 1, l.total);
    }

    #[test]
    fn layout_linear_has_only_lr() {
        let cfg = ModelConfig::linear(8, 128);
        let l = Layout::new(&cfg);
        assert_eq!(l.total, 128);
        assert_eq!(l.ffm_len, 0);
        assert!(l.layers.is_empty());
        assert_eq!(l.w_out_len, 0);
    }

    #[test]
    fn layout_ffm_no_mlp() {
        let cfg = ModelConfig::ffm(4, 2, 64);
        let l = Layout::new(&cfg);
        assert_eq!(l.total, 64 + 64 * 8);
        assert!(l.layers.is_empty());
    }

    #[test]
    fn ffm_idx_math() {
        let cfg = ModelConfig::ffm(4, 2, 64);
        let l = Layout::new(&cfg);
        // bucket 3, toward field 2, component 1
        assert_eq!(l.ffm_idx(3, 4, 2, 2, 1), 64 + 3 * 8 + 2 * 2 + 1);
    }

    #[test]
    fn init_deterministic_and_seed_sensitive() {
        let cfg = ModelConfig::deep_ffm(4, 2, 64, &[8]);
        let l = Layout::new(&cfg);
        let a = WeightPool::init(&cfg, &l);
        let b = WeightPool::init(&cfg, &l);
        assert_eq!(a.weights, b.weights);
        let mut cfg2 = cfg.clone();
        cfg2.seed = 1;
        let c = WeightPool::init(&cfg2, &l);
        assert_ne!(a.weights, c.weights);
    }

    #[test]
    fn init_ranges() {
        let cfg = ModelConfig::deep_ffm(4, 2, 64, &[8]);
        let l = Layout::new(&cfg);
        let p = WeightPool::init(&cfg, &l);
        // LR zeros
        assert!(p.weights[..64].iter().all(|&w| w == 0.0));
        // FFM within init span
        assert!(p.weights[l.ffm_off..l.ffm_off + l.ffm_len]
            .iter()
            .all(|&w| w.abs() <= cfg.init_ffm));
        // not all zero
        assert!(p.weights[l.ffm_off..l.ffm_off + l.ffm_len]
            .iter()
            .any(|&w| w != 0.0));
        // biases zero
        let lay = l.layers[0];
        assert!(p.weights[lay.b_off..lay.b_off + lay.cols]
            .iter()
            .all(|&w| w == 0.0));
        // acc starts at 1
        assert!(p.acc.iter().all(|&a| a == 1.0));
    }

    #[test]
    fn inference_pool_drops_acc() {
        let cfg = ModelConfig::ffm(4, 2, 64);
        let l = Layout::new(&cfg);
        let p = WeightPool::init(&cfg, &l);
        let inf = p.to_inference();
        assert!(!inf.has_optimizer_state());
        assert_eq!(inf.weights, p.weights);
        assert_eq!(inf.inference_bytes(), l.total * 4);
    }
}
