//! §5 — the serving layer.
//!
//! "Each request can be separated into context and candidates.  For all
//! candidates in the request, the context is the same" — the serving
//! types below encode that split directly, and the per-worker
//! [`context_cache`] exploits it.
//!
//! Components:
//! * [`ModelHandle`] — hot-swappable model slot (the §6 update pipeline
//!   swaps a new weight set in without pausing serving).
//! * [`router`] — model registry + context-affinity worker sharding.
//! * [`batcher`] — dynamic candidate batching with linger deadline.
//! * [`context_cache`] — radix-tree cache of partial forwards.
//! * [`server`] — the thread-pool serving engine with latency metrics.
//! * [`trace`] — synthetic production-traffic generator (Figures 4/5).

pub mod batcher;
pub mod context_cache;
pub mod overload;
pub mod router;
pub mod server;
pub mod trace;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::feature::FeatureSlot;
use crate::model::regressor::Regressor;

/// Why admission control shed a request (the overload plane's two
/// casualty classes — see [`crate::config::ShedPolicy`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// Rejected at submit: the worker queue was full under
    /// `reject-new`.
    QueueFull,
    /// Evicted from the queue after admission: a later request
    /// displaced this one under `drop-oldest`.
    DroppedOldest,
}

impl ShedReason {
    pub fn label(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::DroppedOldest => "dropped-oldest",
        }
    }
}

/// Serving-path errors, distinguishable by class so callers can retry
/// sheds elsewhere, drop expired work, and alert on scoring failures.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Shed by admission control — never entered scoring.
    Shed(ShedReason),
    /// Expired in the queue: its SLO budget ran out before a worker
    /// flushed it, so the engine fast-failed it instead of burning
    /// kernel time on a reply nobody is waiting for.
    DeadlineExpired { waited_us: u64, slo_us: u64 },
    /// The engine is (or went) down.
    ShutDown,
    /// Per-request scoring failure (unknown model, malformed slate...).
    Scoring(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Shed(r) => write!(f, "shed ({})", r.label()),
            ServeError::DeadlineExpired { waited_us, slo_us } => {
                write!(f, "deadline expired (waited {waited_us}us, slo {slo_us}us)")
            }
            ServeError::ShutDown => write!(f, "engine is shut down"),
            ServeError::Scoring(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<ServeError> for String {
    fn from(e: ServeError) -> String {
        e.to_string()
    }
}

/// A scoring request: one shared context, many candidates.
#[derive(Clone, Debug)]
pub struct Request {
    /// Model to score with (registered name).
    pub model: String,
    /// Context feature slots (fields `0..C` of the model).
    pub context: Vec<FeatureSlot>,
    /// Candidate slot groups (fields `C..F` each).
    pub candidates: Vec<Vec<FeatureSlot>>,
}

/// Scores for one request's candidates, in order.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub scores: Vec<f32>,
}

/// Hot-swappable model slot.
///
/// Readers take a cheap `Arc` clone of the current model; the update
/// pipeline swaps in a new `Arc` atomically and bumps the version so
/// caches keyed on stale weights invalidate themselves.
///
/// The version and the model live under ONE lock and must be read
/// together via [`load_versioned`](Self::load_versioned) when the
/// version keys cached derived state: reading them through separate
/// calls can pair version N with the model of version N+1 across a
/// concurrent swap, which lets a scorer mix a stale cached partial
/// with fresh weights (a torn response — the §5/§6 invariant the
/// deployment soak test asserts never happens).
#[derive(Clone)]
pub struct ModelHandle {
    inner: Arc<RwLock<(u64, Arc<Regressor>)>>,
    /// Mirror of the locked version for cheap lock-free reads.
    version: Arc<AtomicU64>,
}

impl std::fmt::Debug for ModelHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelHandle").finish_non_exhaustive()
    }
}

impl ModelHandle {
    pub fn new(reg: Regressor) -> Self {
        Self::at_version(reg, 1)
    }

    /// Construct at an explicit starting version — crash recovery
    /// restores a handle at its checkpointed version so the served
    /// version line stays monotonic across a restart instead of
    /// resetting to 1 (version-keyed caches would otherwise collide
    /// with pre-crash entries).
    pub fn at_version(reg: Regressor, version: u64) -> Self {
        ModelHandle {
            inner: Arc::new(RwLock::new((version, Arc::new(reg)))),
            version: Arc::new(AtomicU64::new(version)),
        }
    }

    /// Current model snapshot.
    ///
    /// Lock-poison recovery: the slot is written in one assignment
    /// under the write guard (never left half-updated), so a poisoned
    /// lock's `(version, Arc)` pair is still coherent — serve from it
    /// rather than cascading one panicked thread into a fleet-wide
    /// serving outage.
    pub fn load(&self) -> Arc<Regressor> {
        self.inner
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .1
            .clone()
    }

    /// Current (version, model) pair, read atomically with respect to
    /// [`swap`](Self::swap).
    pub fn load_versioned(&self) -> (u64, Arc<Regressor>) {
        // poison recovery: see `load`
        let slot = self.inner.read().unwrap_or_else(|e| e.into_inner());
        (slot.0, slot.1.clone())
    }

    /// Swap in a new model (returns the new version).
    pub fn swap(&self, reg: Regressor) -> u64 {
        // poison recovery: see `load`
        let mut slot = self.inner.write().unwrap_or_else(|e| e.into_inner());
        slot.0 += 1;
        slot.1 = Arc::new(reg);
        // ordering: Release publishes the bumped version only after the
        // slot assignment above is complete, pairing with the Acquire
        // in `version()` so a lock-free reader that observes version N
        // can never then read pre-N state through the lock.
        self.version.store(slot.0, Ordering::Release);
        slot.0
    }

    /// Monotonic version, bumped on every swap.  May lag a concurrent
    /// [`swap`](Self::swap) by an instant — key caches via
    /// [`load_versioned`](Self::load_versioned) instead.
    pub fn version(&self) -> u64 {
        // ordering: Acquire pairs with the Release store in `swap` —
        // observing version N here happens-after the swap that
        // published it, so version-keyed cache invalidation is never
        // ahead of the model it keys.
        self.version.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn model_handle_swap_bumps_version() {
        let cfg = ModelConfig::linear(4, 256);
        let h = ModelHandle::new(Regressor::new(&cfg));
        assert_eq!(h.version(), 1);
        let m1 = h.load();
        let mut cfg2 = cfg.clone();
        cfg2.seed = 9;
        let v = h.swap(Regressor::new(&cfg2));
        assert_eq!(v, 2);
        assert_eq!(h.version(), 2);
        let m2 = h.load();
        // old snapshot still alive (readers never block swaps)
        assert_eq!(m1.cfg.seed, cfg.seed);
        assert_eq!(m2.cfg.seed, 9);
    }

    #[test]
    fn handle_clones_share_state() {
        let cfg = ModelConfig::linear(4, 256);
        let h = ModelHandle::new(Regressor::new(&cfg));
        let h2 = h.clone();
        h.swap(Regressor::new(&cfg));
        assert_eq!(h2.version(), 2);
    }

    #[test]
    fn load_versioned_pairs_stay_consistent_under_swaps() {
        // hammer load_versioned from readers while a writer swaps:
        // every observed (version, model) pair must be self-consistent
        // (the model's seed encodes the version that published it)
        let cfg = ModelConfig::linear(4, 256);
        let h = ModelHandle::new(Regressor::new(&cfg));
        let writer = {
            let h = h.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                for v in 2..=50u64 {
                    let mut c = cfg.clone();
                    c.seed = v; // model carries its publish version
                    h.swap(Regressor::new(&c));
                }
            })
        };
        let mut last = 0u64;
        while last < 50 {
            let (version, model) = h.load_versioned();
            if version > 1 {
                assert_eq!(
                    model.cfg.seed, version,
                    "torn (version, model) pair observed"
                );
            }
            assert!(version >= last, "version went backwards");
            last = version;
        }
        writer.join().unwrap();
    }
}
