//! Cross-plane chaos soak (the crash-recovery PR's tentpole test):
//!
//!   Hogwild rounds ──► fleet fabric (retrying publishes, health
//!   ladder) ──► live serving engines — while a seed-derived fault
//!   schedule kills and restarts replicas, restores the whole fabric
//!   from its on-disk checkpoint, partitions DCs and stalls replicas,
//!   and traffic threads keep scoring probes through the health board
//!   the entire time.
//!
//! Per run:
//!   (a) zero torn responses anywhere, even across restarts,
//!   (b) every fault kind fired at least once (crash/restore included),
//!   (c) after the final catch-up every replica is bit-identical to the
//!       reference reconstruction,
//!   (d) the recovery plane left its trace in the shared registry:
//!       health transitions, publish retries, replay timings.
//!
//! Every run prints `chaos seed: 0x...` first; any failure reproduces
//! from that one number (`fw fleet --chaos --seed N`).

// Soak/e2e scale: far too slow under the Miri interpreter (~1000x);
// the nightly Miri job covers the scalar kernels and unit props
// instead.
#![cfg(not(miri))]

use fwumious::fleet::chaos::{run_chaos_soak, ChaosConfig};
use fwumious::transfer::UpdateMode;

/// The ISSUE-scale soak: ≥20 rounds with live traffic on the
/// production configuration (quantized patches, the mode with the most
/// recovery machinery in play: folded-chain replays AND resyncs).
#[test]
fn chaos_soak_full_quant_patch() {
    let cfg = ChaosConfig::full(UpdateMode::QuantPatch, 0x5eed_c4a0);
    assert!(cfg.rounds >= 20 && cfg.dcs >= 3);
    let report = run_chaos_soak(cfg);
    report.assert_healthy();
    assert_eq!(report.rounds.len(), 24);
    // the board actually steered traffic around unhealthy replicas at
    // some point (stall + partition both walk replicas off the ladder)
    assert!(
        report.routed_around >= 1,
        "seed {:#x}: no request was ever routed around",
        report.seed
    );
}

/// Raw full files: recovery never needs the patch log — restores and
/// restarts must still be bit-identical with an empty replay window.
#[test]
fn chaos_soak_raw_mode() {
    let report = run_chaos_soak(ChaosConfig::smoke(UpdateMode::Raw, 0x0a11));
    report.assert_healthy();
    assert_eq!(report.metrics.replays, 0);
}

/// Quantized full files: restore must rebuild the dequantized
/// reference from the checkpointed base bytes, not re-quantize.
#[test]
fn chaos_soak_quant_mode() {
    run_chaos_soak(ChaosConfig::smoke(UpdateMode::Quant, 0x9a11)).assert_healthy();
}

/// Lossless delta chains: crashes land mid-chain, so restarts exercise
/// the cursor→head replay path (folded or sequential).
#[test]
fn chaos_soak_patch_only_mode() {
    let report =
        run_chaos_soak(ChaosConfig::smoke(UpdateMode::PatchOnly, 0x9a7c));
    report.assert_healthy();
    assert!(
        report.metrics.replays + report.metrics.resyncs >= 1,
        "seed {:#x}: chained mode never caught up",
        report.seed
    );
}
