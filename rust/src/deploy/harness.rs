//! Deterministic soak harness for the deployment plane.
//!
//! Runs N train→publish→swap rounds while traffic-driver threads score
//! a fixed probe set through [`ServeClient`] clones, and checks the
//! three §5/§6 invariants the paper's always-online regime depends on:
//!
//! 1. **Atomic swaps** — every served response matches, bit for bit,
//!    the scores of exactly one published snapshot (the previous or the
//!    freshly swapped one) — never a torn mix of two weight sets.
//!    Expected scores are registered *before* each swap, so concurrent
//!    traffic can always attribute a response to a known version.
//! 2. **Bit-identical reconstruction** — after every round the
//!    receiver's base file equals the sender's byte-for-byte, and for
//!    the quantized modes the served weights are exactly the
//!    dequantized receiver-side bytes.
//! 3. **Learning continuity** — held-out AUC of the *served* model is
//!    non-decreasing across rounds within a tolerance (publishing must
//!    not regress the model).
//!
//! The harness is deterministic in its inputs (seeded streams, fixed
//! probe set); Hogwild thread interleaving may perturb the trained
//! weights, which the AUC tolerance absorbs.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

use crate::config::{ModelConfig, ServeConfig};
use crate::data::synthetic::DatasetSpec;
use crate::deploy::{DeployConfig, DeploymentLoop, RoundReport};
use crate::model::regressor::Regressor;
use crate::model::Workspace;
use crate::quant;
use crate::serve::server::{ServeClient, ServeStats};
use crate::serve::trace::TraceGenerator;
use crate::serve::Request;
use crate::transfer::UpdateMode;

/// Soak run parameters.
#[derive(Clone, Debug)]
pub struct SoakConfig {
    pub mode: UpdateMode,
    /// Train→publish→swap rounds to run.
    pub rounds: usize,
    /// Examples per training round.
    pub examples_per_round: usize,
    /// Hogwild threads inside each round.
    pub train_threads: usize,
    /// Concurrent traffic-driver threads.
    pub traffic_threads: usize,
    /// Distinct probe requests in the fixed set.
    pub probes: usize,
    /// Base seed (streams, probes).
    pub seed: u64,
}

impl SoakConfig {
    /// A configuration small enough for `cargo test` yet exercising
    /// real concurrency: 5 rounds, Hogwild ×2, 2 traffic threads.
    pub fn quick(mode: UpdateMode) -> Self {
        SoakConfig {
            mode,
            rounds: 5,
            examples_per_round: 2_500,
            train_threads: 2,
            traffic_threads: 2,
            probes: 16,
            seed: 0x50a4,
        }
    }
}

/// Everything a soak run observed; see [`SoakReport::assert_healthy`].
#[derive(Clone, Debug)]
pub struct SoakReport {
    pub mode: UpdateMode,
    pub rounds: Vec<RoundReport>,
    /// Probe responses checked across all traffic threads.
    pub probe_checks: u64,
    /// Responses that matched NO published snapshot (must be 0).
    pub torn_responses: u64,
    /// Distinct model versions observed being served.
    pub versions_observed: usize,
    /// Rounds where sender/receiver base files diverged (must be empty).
    pub base_mismatch_rounds: Vec<usize>,
    /// Rounds where served weights != dequantized receiver bytes
    /// (quantized modes only; must be empty).
    pub quant_mismatch_rounds: Vec<usize>,
    /// Held-out AUC of the served model after each swap.
    pub holdout_aucs: Vec<f64>,
    /// Final serving statistics.
    pub serve_stats: ServeStats,
    /// Total bytes shipped over the simulated channel.
    pub shipped_bytes: u64,
    /// Raw-file bytes the same rounds would have shipped unencoded.
    pub raw_bytes: u64,
}

impl SoakReport {
    /// Panic (with context) unless every invariant held.
    ///
    /// `auc_tolerance` bounds the allowed per-round AUC decrease —
    /// Hogwild nondeterminism and quantization jitter, not publishing,
    /// are the only legitimate sources of decrease.
    pub fn assert_healthy(&self, auc_tolerance: f64) {
        let mode = self.mode;
        assert_eq!(
            self.torn_responses, 0,
            "{mode:?}: {} of {} responses matched no published snapshot",
            self.torn_responses, self.probe_checks
        );
        assert!(
            self.probe_checks > 0,
            "{mode:?}: traffic drivers never scored a probe"
        );
        assert!(
            self.versions_observed >= 2,
            "{mode:?}: only {} version(s) observed — no live swap was served",
            self.versions_observed
        );
        assert!(
            self.base_mismatch_rounds.is_empty(),
            "{mode:?}: sender/receiver bases diverged in rounds {:?}",
            self.base_mismatch_rounds
        );
        assert!(
            self.quant_mismatch_rounds.is_empty(),
            "{mode:?}: served weights != dequantized bytes in rounds {:?}",
            self.quant_mismatch_rounds
        );
        for w in self.holdout_aucs.windows(2) {
            assert!(
                w[1] >= w[0] - auc_tolerance,
                "{mode:?}: held-out AUC regressed {} -> {} (tol {auc_tolerance})",
                w[0],
                w[1]
            );
        }
        // NaN for a zero-round run: it fails the assert below with the
        // run's real defect (no rounds) visible in the message.
        let last = self.holdout_aucs.last().copied().unwrap_or(f64::NAN);
        assert!(last > 0.55, "{mode:?}: final held-out AUC {last} at chance");
        assert_eq!(self.serve_stats.errors, 0, "{mode:?}: serving errors");
        assert!(self.serve_stats.requests >= self.probe_checks);
    }
}

/// Expected probe scores of one published snapshot, computed through
/// the same partial-forward path the serving workers use.  Public
/// because the fleet-wide soak ([`crate::fleet::soak`]) registers the
/// same per-version expectations across every replica's engine.
pub fn probe_scores(reg: &Regressor, probes: &[Request]) -> Vec<Vec<f32>> {
    let mut ws = Workspace::new();
    probes
        .iter()
        .map(|req| {
            let cp = reg.context_partial(&req.context);
            req.candidates
                .iter()
                .map(|cand| reg.predict_with_partial(&cp, cand, &mut ws))
                .collect()
        })
        .collect()
}

/// Published snapshots: (version, per-probe expected scores).
type Published = Arc<RwLock<Vec<(u64, Vec<Vec<f32>>)>>>;

fn traffic_driver(
    client: ServeClient,
    probes: Vec<Request>,
    published: Published,
    stop: Arc<AtomicBool>,
    offset: usize,
) -> (u64, u64, HashSet<u64>) {
    let mut checks = 0u64;
    let mut torn = 0u64;
    let mut versions = HashSet::new();
    let mut i = offset;
    // ordering: Relaxed — the flag only ends the loop; drivers join
    // afterwards, so no data is published through it.
    while !stop.load(Ordering::Relaxed) {
        let idx = i % probes.len();
        i += 1;
        let resp = match client.score(probes[idx].clone()) {
            Ok(r) => r,
            Err(_) => break, // engine shut down under us
        };
        checks += 1;
        // Poison recovery: snapshots are appended whole under the
        // guard, so a poisoned lock still holds every complete entry.
        let reg = published.read().unwrap_or_else(|e| e.into_inner());
        // newest first: steady state hits the fresh snapshot immediately
        match reg
            .iter()
            .rev()
            .find(|(_, scores)| scores[idx] == resp.scores)
        {
            Some((version, _)) => {
                versions.insert(*version);
            }
            None => torn += 1,
        }
    }
    (checks, torn, versions)
}

/// Run one soak: N concurrent train/transfer/serve rounds, returning
/// every observation.  Panics only on plumbing failures; invariant
/// verdicts live in the report (see [`SoakReport::assert_healthy`]).
pub fn run_soak(cfg: SoakConfig) -> SoakReport {
    // 5-field tiny-shaped task: 1 continuous + 4 categorical.
    let mut spec = DatasetSpec::tiny();
    spec.cat_fields = 4;
    let fields = spec.fields();
    let model = ModelConfig::deep_ffm(fields, 2, 1 << 12, &[8]);
    let mut dcfg = DeployConfig::new(model, spec, cfg.mode);
    dcfg.examples_per_round = cfg.examples_per_round;
    dcfg.train_threads = cfg.train_threads;
    dcfg.seed = cfg.seed;
    dcfg.serve = ServeConfig {
        workers: 2,
        max_batch: 32,
        max_wait_us: 100,
        context_cache_entries: 4_096,
        max_group_candidates: 1024,
        ..ServeConfig::default()
    };
    let mut dl = DeploymentLoop::new(dcfg);

    // Fixed probe set (2 context fields, 4 candidates each).
    let mut gen = TraceGenerator::new(
        cfg.seed ^ 0x7ea5,
        fields,
        2,
        dl.cfg.model.buckets,
        4,
    );
    let probes: Vec<Request> = (0..cfg.probes.max(1))
        .map(|_| gen.next_request(&dl.cfg.model_name))
        .collect();

    // Register the bootstrap snapshot (version 1) before any traffic.
    let published: Published = Arc::new(RwLock::new(vec![(
        dl.handle().version(),
        probe_scores(&dl.handle().load(), &probes),
    )]));
    let stop = Arc::new(AtomicBool::new(false));

    let mut drivers = Vec::new();
    for t in 0..cfg.traffic_threads.max(1) {
        let client = dl.client();
        let probes = probes.clone();
        let published = published.clone();
        let stop = stop.clone();
        drivers.push(
            std::thread::Builder::new()
                .name(format!("fw-soak-traffic-{t}"))
                .spawn(move || traffic_driver(client, probes, published, stop, t))
                .unwrap_or_else(|e| {
                    // a soak without its drivers observes nothing
                    panic!("cannot spawn traffic driver {t}: {e}")
                }),
        );
    }

    let mut rounds = Vec::with_capacity(cfg.rounds);
    let mut base_mismatch_rounds = Vec::new();
    let mut quant_mismatch_rounds = Vec::new();
    for r in 0..cfg.rounds {
        let published2 = published.clone();
        let probes_ref = &probes;
        let report = dl
            .run_round_with(|fresh, version| {
                let scores = probe_scores(fresh, probes_ref);
                // poison recovery: see `traffic_driver`
                published2
                    .write()
                    .unwrap_or_else(|e| e.into_inner())
                    .push((version, scores));
            })
            .unwrap_or_else(|e| panic!("round {r} failed: {e}"));

        // invariant 2: bit-identical reconstruction
        if dl.pipeline().sent_bytes() != dl.receiver().base_bytes() {
            base_mismatch_rounds.push(r);
        }
        if cfg.mode.is_quantized() {
            let served = dl.handle().load();
            let ok = dl
                .receiver()
                .base_bytes()
                .and_then(|b| quant::dequantize_from_bytes(b).ok())
                .is_some_and(|deq| deq == served.pool.weights);
            if !ok {
                quant_mismatch_rounds.push(r);
            }
        }
        rounds.push(report);
    }

    // ordering: Relaxed — see the load in `traffic_driver`.
    stop.store(true, Ordering::Relaxed);
    let mut probe_checks = 0u64;
    let mut torn_responses = 0u64;
    let mut versions = HashSet::new();
    for d in drivers {
        let (c, t, v) = match d.join() {
            Ok(r) => r,
            // re-raise the driver's own panic (its message carries the
            // failed invariant) instead of a generic join failure
            Err(payload) => std::panic::resume_unwind(payload),
        };
        probe_checks += c;
        torn_responses += t;
        versions.extend(v);
    }

    let holdout_aucs = rounds.iter().map(|r| r.holdout_auc).collect();
    let shipped_bytes = dl.channel().total_bytes;
    let raw_bytes = dl.metrics().raw_bytes_total;
    let mode = cfg.mode;
    let serve_stats = dl.shutdown();
    SoakReport {
        mode,
        rounds,
        probe_checks,
        torn_responses,
        versions_observed: versions.len(),
        base_mismatch_rounds,
        quant_mismatch_rounds,
        holdout_aucs,
        serve_stats,
        shipped_bytes,
        raw_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_scores_match_direct_prediction() {
        let cfg = ModelConfig::deep_ffm(5, 2, 1 << 10, &[8]);
        let reg = Regressor::new(&cfg);
        let mut gen = TraceGenerator::new(3, 5, 2, 1 << 10, 4);
        let probes: Vec<Request> = (0..4).map(|_| gen.next_request("m")).collect();
        let scores = probe_scores(&reg, &probes);
        assert_eq!(scores.len(), 4);
        let mut ws = Workspace::new();
        for (req, row) in probes.iter().zip(&scores) {
            assert_eq!(row.len(), req.candidates.len());
            let cp = reg.context_partial(&req.context);
            for (cand, &s) in req.candidates.iter().zip(row) {
                assert_eq!(s, reg.predict_with_partial(&cp, cand, &mut ws));
            }
        }
    }

    #[test]
    fn tiny_soak_smoke() {
        // 2 rounds only: the full ≥5-round soaks for all four modes run
        // in tests/online_deploy_e2e.rs
        let mut cfg = SoakConfig::quick(UpdateMode::Raw);
        cfg.rounds = 2;
        cfg.examples_per_round = 800;
        let report = run_soak(cfg);
        assert_eq!(report.rounds.len(), 2);
        assert_eq!(report.torn_responses, 0);
        assert!(report.base_mismatch_rounds.is_empty());
    }
}
