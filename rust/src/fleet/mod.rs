//! The multi-data-center weight distribution fabric.
//!
//! PR 1's deployment plane runs exactly one trainer→server pipe.  The
//! paper's regime is a *fleet*: one training site continuously
//! publishing to N data centers × M replicas each, where cross-DC
//! bandwidth is the billed resource and every replica must keep
//! serving a consistent version while updates race across lossy links.
//! This module is that fan-out layer:
//!
//! ```text
//!                      ┌────────────── DC 0 ──────────────┐
//!            inter-DC  │  head ──intra──► replica 1..M-1  │
//!   trainer ══════════►│  (fan-out tree: 1 WAN crossing)  │
//!      ║               └──────────────────────────────────┘
//!      ║  star: M WAN crossings per DC instead
//!      ╚══════════════► DC 1 … DC N-1   (same choice per DC)
//! ```
//!
//! * [`topology`] — DCs, replicas, per-link bandwidth/RTT/loss.
//! * [`planner`] — star vs fan-out-tree routes, chosen to minimize
//!   inter-DC bytes (the §6 bandwidth trick, generalized).
//! * [`replica`] — per-replica delta-chain version tracking over
//!   [`crate::transfer::UpdateReceiver`].
//! * [`FleetFabric`] — encode once, distribute per plan, heal broken
//!   chains via the catch-up protocol (chained-patch replay vs
//!   full-snapshot resync, whichever ships fewer bytes).
//! * [`metrics`] — per-link byte ledgers, publish lag per replica,
//!   max version skew, convergence counters.
//! * [`soak`] — the fleet-wide soak harness (the deployment-plane soak
//!   of [`crate::deploy::harness`], scaled out to ≥3 DCs × ≥2
//!   replicas with fault injection).

pub mod metrics;
pub mod planner;
pub mod replica;
pub mod soak;
pub mod topology;

pub use metrics::{FleetMetrics, LagStat, LinkLedger};
pub use planner::{plan, DcRoute, DistributionPlan, Strategy};
pub use replica::{ApplyVerdict, FleetReplica};
pub use topology::{DcSpec, LinkSpec, ReplicaId, SimLink, Topology};

use crate::config::ServeConfig;
use crate::model::regressor::Regressor;
use crate::obs::RequestTracer;
use crate::serve::server::ServeStats;
use crate::transfer::{UpdateMode, UpdatePipeline, UpdateReceiver};
use crate::util::json::{num, obj, s};
use crate::util::rng::Pcg32;

/// Configuration of one fleet fabric.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub topology: Topology,
    /// Wire encoding (the four Table-4 arms).
    pub mode: UpdateMode,
    /// Route policy resolved by the [`planner`] each round.
    pub strategy: Strategy,
    /// Catch-up window: a replica at most this many updates behind may
    /// be healed by replaying the retained patch chain; farther behind
    /// (or when replay would cost more bytes than a full file) it gets
    /// a full-snapshot resync.
    pub max_chain: usize,
    /// Start a live serving engine per replica (None = headless
    /// distribution sim — links and versions only).
    pub serve: Option<ServeConfig>,
    /// Name replicas register their model under.
    pub model_name: String,
    /// Seed for the deterministic loss simulation.
    pub seed: u64,
}

impl FleetConfig {
    pub fn new(topology: Topology, mode: UpdateMode) -> Self {
        FleetConfig {
            topology,
            mode,
            strategy: Strategy::Auto,
            max_chain: 8,
            serve: None,
            model_name: "ctr".into(),
            seed: 0xf1ee7,
        }
    }
}

/// How a catch-up was resolved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CatchUpKind {
    /// Replica was already at head; nothing shipped.
    None,
    /// Replayed this many retained chained updates, in order.
    Replay { updates: usize },
    /// Shipped a full snapshot of this many bytes.
    Resync { bytes: usize },
}

/// Everything observed about one publish round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// Publish sequence number of this round's update (1-based).
    pub seq: u64,
    /// Bytes of the encoded update on the wire.
    pub update_bytes: usize,
    /// Size of the raw inference file (the baseline).
    pub raw_bytes: usize,
    /// Replicas that received this round's update via distribution or
    /// were pulled to head by catch-up during the round.
    pub delivered: usize,
    /// Shipments lost this round (replicas left behind).
    pub dropped: usize,
    /// Catch-ups resolved by patch-chain replay this round.
    pub replays: u64,
    /// Catch-ups resolved by full resync this round.
    pub resyncs: u64,
    /// `head - min(replica seq)` after the round.
    pub max_skew: u64,
    /// Encoder wall time.
    pub encode_seconds: f64,
}

/// The distribution fabric: one sender-side pipeline fanned out to
/// every replica in the topology over simulated links.
pub struct FleetFabric {
    cfg: FleetConfig,
    pipeline: UpdatePipeline,
    /// In-order receiver that never misses an update: the reference
    /// every replica must converge to, and the source of pre-swap
    /// expected state for the soak's torn-response check.
    reference: UpdateReceiver,
    reference_model: Option<Regressor>,
    /// Retained per-round updates (`log[i]` is publish seq `i+1`) —
    /// the sender side of the catch-up replay path.
    log: Vec<crate::transfer::WireUpdate>,
    /// Everything before this index is already payload-blanked, so
    /// [`compact_log`](Self::compact_log) stays O(1) per round.
    log_blanked: usize,
    head: u64,
    replicas: Vec<FleetReplica>,
    /// Per-DC trainer→DC links.
    inter: Vec<SimLink>,
    /// Per-DC intra-DC re-distribution links.
    intra: Vec<SimLink>,
    rng: Pcg32,
    /// Fault injector: force-drop the next N shipments.
    forced_drops: u32,
    rounds: u64,
    max_skew: u64,
    replays: u64,
    resyncs: u64,
    converged_rounds: u64,
    lag: Vec<LagStat>,
    /// Discrete-event sink (publish rounds, catch-up replays/resyncs);
    /// None = no tracing cost beyond this Option check.
    tracer: Option<RequestTracer>,
}

impl FleetFabric {
    /// Build the fleet: every replica bootstraps from `template`
    /// (structure + initial weights) at sequence 0.
    pub fn new(cfg: FleetConfig, template: &Regressor) -> Self {
        let mut reference = UpdateReceiver::new(cfg.mode);
        reference.set_template(template.clone());
        let replicas: Vec<FleetReplica> = cfg
            .topology
            .replica_ids()
            .into_iter()
            .map(|id| {
                FleetReplica::new(
                    id,
                    cfg.mode,
                    template,
                    cfg.serve.as_ref(),
                    &cfg.model_name,
                )
            })
            .collect();
        let inter = cfg.topology.dcs.iter().map(|d| SimLink::new(d.inter)).collect();
        let intra = cfg.topology.dcs.iter().map(|d| SimLink::new(d.intra)).collect();
        let rng = Pcg32::seeded(cfg.seed);
        let lag = vec![LagStat::default(); replicas.len()];
        let pipeline = UpdatePipeline::new(cfg.mode);
        FleetFabric {
            cfg,
            pipeline,
            reference,
            reference_model: None,
            log: Vec::new(),
            log_blanked: 0,
            head: 0,
            replicas,
            inter,
            intra,
            rng,
            forced_drops: 0,
            rounds: 0,
            max_skew: 0,
            replays: 0,
            resyncs: 0,
            converged_rounds: 0,
            lag,
            tracer: None,
        }
    }

    /// Attach a discrete-event tracer: publish rounds and catch-up
    /// replays/resyncs are emitted as JSONL events.
    pub fn set_tracer(&mut self, tracer: RequestTracer) {
        self.tracer = Some(tracer);
    }

    /// Publish one trained snapshot to the whole fleet.
    pub fn publish(&mut self, reg: &Regressor) -> Result<RoundOutcome, String> {
        self.publish_with(reg, |_, _| {})
    }

    /// [`publish`](Self::publish) with a hook that observes the
    /// reconstructed model *before any replica can swap it in* — the
    /// soak harness registers expected probe scores there, so traffic
    /// hitting any replica can always attribute a response to a known
    /// version (the fleet-wide torn-response invariant).
    pub fn publish_with(
        &mut self,
        reg: &Regressor,
        before_swap: impl FnOnce(u64, &Regressor),
    ) -> Result<RoundOutcome, String> {
        let seq = self.head + 1;
        let update = self.pipeline.encode(reg);
        let raw_bytes = self.pipeline.last_raw_len().unwrap_or(0);
        let fresh = self.reference.apply(&update)?;
        before_swap(seq, &fresh);
        self.reference_model = Some(fresh);
        let update_bytes = update.bytes.len();
        let encode_seconds = update.encode_seconds;
        self.log.push(update);
        self.head = seq;

        let plan = planner::plan(&self.cfg.topology, self.cfg.strategy);
        let mut delivered = 0usize;
        let mut dropped = 0usize;
        let replays0 = self.replays;
        let resyncs0 = self.resyncs;
        for (dc, route) in plan.per_dc.iter().enumerate() {
            let n_replicas = self.cfg.topology.dcs[dc].replicas;
            match route {
                DcRoute::Star => {
                    for r in 0..n_replicas {
                        match self.ship_inter(dc, update_bytes) {
                            Some(secs) => {
                                self.apply_at(dc, r, encode_seconds + secs)?;
                                delivered += 1;
                            }
                            None => dropped += 1,
                        }
                    }
                }
                DcRoute::Tree { head } => {
                    match self.ship_inter(dc, update_bytes) {
                        None => dropped += n_replicas,
                        Some(head_secs) => {
                            self.apply_at(dc, *head, encode_seconds + head_secs)?;
                            delivered += 1;
                            for r in 0..n_replicas {
                                if r == *head {
                                    continue;
                                }
                                match self.ship_intra(dc, update_bytes) {
                                    Some(secs) => {
                                        self.apply_at(
                                            dc,
                                            r,
                                            encode_seconds + head_secs + secs,
                                        )?;
                                        delivered += 1;
                                    }
                                    None => dropped += 1,
                                }
                            }
                        }
                    }
                }
            }
        }

        self.compact_log();
        let max_skew = self.current_skew();
        self.max_skew = self.max_skew.max(max_skew);
        self.rounds += 1;
        if max_skew == 0 {
            self.converged_rounds += 1;
        }
        if let Some(tr) = self.tracer.as_ref() {
            tr.emit(&obj(vec![
                ("event", s("fleet_publish")),
                ("seq", num(seq as f64)),
                ("update_bytes", num(update_bytes as f64)),
                ("delivered", num(delivered as f64)),
                ("dropped", num(dropped as f64)),
                ("max_skew", num(max_skew as f64)),
            ]));
        }
        Ok(RoundOutcome {
            seq,
            update_bytes,
            raw_bytes,
            delivered,
            dropped,
            replays: self.replays - replays0,
            resyncs: self.resyncs - resyncs0,
            max_skew,
            encode_seconds,
        })
    }

    /// Bring replica `idx` (flattened DC-major index) to the head
    /// version.  The catch-up protocol: when the replica's mode chains
    /// updates, it is within the replay window, and the retained
    /// patches sum to fewer bytes than a full snapshot, the missed
    /// chain is replayed in order; otherwise a full-snapshot resync
    /// ships the sender's current base file.  Catch-up payloads move
    /// over a *reliable* control channel (lost shipments are
    /// retransmitted and billed).
    pub fn catch_up(&mut self, idx: usize) -> Result<CatchUpKind, String> {
        let from = self.replicas[idx].seq();
        if from >= self.head {
            return Ok(CatchUpKind::None);
        }
        let dc = self.replicas[idx].id.dc;
        let missed = (self.head - from) as usize;
        let replay_bytes: usize = self.log[from as usize..self.head as usize]
            .iter()
            .map(|u| u.bytes.len())
            .sum();
        let full_len = self
            .pipeline
            .sent_bytes()
            .map(|b| b.len())
            .ok_or("nothing published yet")?;
        // compact_log guarantees the last max_chain entries are intact;
        // the emptiness check is insurance against window-math drift
        let replay = self.cfg.mode.is_chained()
            && missed <= self.cfg.max_chain
            && replay_bytes < full_len
            && self.log[from as usize..self.head as usize]
                .iter()
                .all(|u| !u.bytes.is_empty());
        if replay {
            for seq in from + 1..=self.head {
                let len = self.log[(seq - 1) as usize].bytes.len();
                let secs = self.ship_reliable_inter(dc, len);
                let verdict =
                    self.replicas[idx].deliver(seq, &self.log[(seq - 1) as usize])?;
                debug_assert_eq!(verdict, ApplyVerdict::Applied);
                self.lag[idx].record(secs);
            }
            self.replays += 1;
            if let Some(tr) = self.tracer.as_ref() {
                tr.emit(&obj(vec![
                    ("event", s("fleet_catch_up")),
                    ("kind", s("replay")),
                    ("replica", num(idx as f64)),
                    ("updates", num(missed as f64)),
                ]));
            }
            Ok(CatchUpKind::Replay { updates: missed })
        } else {
            let full = self
                .pipeline
                .sent_bytes()
                .expect("checked above")
                .to_vec();
            let secs = self.ship_reliable_inter(dc, full.len());
            self.replicas[idx].resync(self.head, &full)?;
            self.lag[idx].record(secs);
            self.resyncs += 1;
            if let Some(tr) = self.tracer.as_ref() {
                tr.emit(&obj(vec![
                    ("event", s("fleet_catch_up")),
                    ("kind", s("resync")),
                    ("replica", num(idx as f64)),
                    ("bytes", num(full.len() as f64)),
                ]));
            }
            Ok(CatchUpKind::Resync { bytes: full.len() })
        }
    }

    /// End-of-run barrier: catch every straggler up to head.  Returns
    /// how many replicas needed it.  (Production runs this implicitly
    /// — the next round's gap triggers the same protocol.)
    pub fn converge(&mut self) -> Result<usize, String> {
        let mut fixed = 0;
        for idx in 0..self.replicas.len() {
            if self.replicas[idx].seq() < self.head {
                self.catch_up(idx)?;
                fixed += 1;
            }
        }
        Ok(fixed)
    }

    /// Force the next `n` shipments (any link) to be lost — the
    /// deterministic fault injector behind the soak/property tests.
    pub fn force_drops(&mut self, n: u32) {
        self.forced_drops += n;
    }

    // ------------------------------------------------------ internals

    fn apply_at(&mut self, dc: usize, r: usize, lag_seconds: f64) -> Result<(), String> {
        let idx = self.cfg.topology.flat_index(ReplicaId { dc, replica: r });
        let seq = self.head;
        let verdict = self.replicas[idx].deliver(seq, &self.log[(seq - 1) as usize])?;
        match verdict {
            ApplyVerdict::Applied => {
                self.lag[idx].record(lag_seconds);
                Ok(())
            }
            ApplyVerdict::Duplicate => Ok(()),
            ApplyVerdict::Gap => {
                // the replica fell behind earlier (dropped update);
                // heal the chain now
                self.catch_up(idx).map(|_| ())
            }
        }
    }

    /// Drop retained payloads that the replay path can never use: the
    /// log keeps one slot per seq (indexing), but only the newest
    /// `max_chain` entries are replayable (and non-chained modes never
    /// replay at all — their catch-up is always a resync of the
    /// current base).  Without this, a long Raw-mode run would retain
    /// every full snapshot ever published.
    fn compact_log(&mut self) {
        let keep = if self.cfg.mode.is_chained() {
            self.cfg.max_chain.max(1)
        } else {
            1
        };
        let blank_upto = self.log.len().saturating_sub(keep);
        let start = self.log_blanked.min(blank_upto);
        for u in &mut self.log[start..blank_upto] {
            u.bytes = Vec::new();
        }
        self.log_blanked = self.log_blanked.max(blank_upto);
    }

    fn take_forced_drop(&mut self) -> bool {
        if self.forced_drops > 0 {
            self.forced_drops -= 1;
            true
        } else {
            false
        }
    }

    fn ship_inter(&mut self, dc: usize, len: usize) -> Option<f64> {
        let force = self.take_forced_drop();
        self.inter[dc].ship(len, &mut self.rng, force)
    }

    fn ship_intra(&mut self, dc: usize, len: usize) -> Option<f64> {
        let force = self.take_forced_drop();
        self.intra[dc].ship(len, &mut self.rng, force)
    }

    /// Reliable (retransmitting) inter-DC shipment for catch-up
    /// traffic; every attempt is billed, delivery is guaranteed.  After
    /// a bounded number of lossy retries the final retransmission is
    /// forced through (and billed as a delivery), so even a 100%-loss
    /// link cannot leave the ledger claiming convergence happened with
    /// zero successful shipments.
    fn ship_reliable_inter(&mut self, dc: usize, len: usize) -> f64 {
        let mut total = 0.0;
        for _ in 0..63 {
            match self.ship_inter(dc, len) {
                Some(secs) => return total + secs,
                None => total += self.inter[dc].spec.transfer_seconds(len),
            }
        }
        let secs = self.inter[dc].spec.transfer_seconds(len);
        self.inter[dc].ledger.record(len, secs, true);
        total + secs
    }

    fn current_skew(&self) -> u64 {
        self.replicas.iter().map(|r| self.head - r.seq()).max().unwrap_or(0)
    }

    // ------------------------------------------------------ accessors

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    pub fn topology(&self) -> &Topology {
        &self.cfg.topology
    }

    /// Current head publish sequence (0 before the first round).
    pub fn head(&self) -> u64 {
        self.head
    }

    /// All replicas, flattened DC-major.
    pub fn replicas(&self) -> &[FleetReplica] {
        &self.replicas
    }

    /// The reference model every replica must converge to (None before
    /// the first publish).
    pub fn reference(&self) -> Option<&Regressor> {
        self.reference_model.as_ref()
    }

    /// Sender-side base file for the current head (the resync payload).
    pub fn sender_base(&self) -> Option<&[u8]> {
        self.pipeline.sent_bytes()
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> FleetMetrics {
        FleetMetrics {
            rounds: self.rounds,
            max_version_skew: self.max_skew,
            replays: self.replays,
            resyncs: self.resyncs,
            converged_rounds: self.converged_rounds,
            lag: self.lag.clone(),
            inter: self.inter.iter().map(|l| l.ledger).collect(),
            intra: self.intra.iter().map(|l| l.ledger).collect(),
        }
    }

    /// Stop all replica engines; returns their final serving stats
    /// (None entries for headless replicas).
    pub fn shutdown(self) -> Vec<Option<ServeStats>> {
        self.replicas.into_iter().map(|r| r.shutdown()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::synthetic::{DatasetSpec, SyntheticStream};
    use crate::model::Workspace;

    fn trained_snapshots(n: usize, per: usize) -> (Regressor, Vec<Regressor>) {
        let cfg = ModelConfig::ffm(4, 2, 1 << 9);
        let template = Regressor::new(&cfg);
        let mut reg = template.clone();
        let mut ws = Workspace::new();
        let mut s = SyntheticStream::with_buckets(DatasetSpec::tiny(), 9, 1 << 9);
        let mut out = Vec::new();
        for _ in 0..n {
            for _ in 0..per {
                let ex = s.next_example();
                reg.learn(&ex, &mut ws);
            }
            out.push(reg.clone());
        }
        (template, out)
    }

    fn fabric(mode: UpdateMode, dcs: usize, replicas: usize, template: &Regressor) -> FleetFabric {
        let topo = Topology::uniform(dcs, replicas, LinkSpec::wan(), LinkSpec::lan());
        FleetFabric::new(FleetConfig::new(topo, mode), template)
    }

    #[test]
    fn lossless_fleet_converges_every_round() {
        for mode in UpdateMode::ALL {
            let (template, snaps) = trained_snapshots(3, 250);
            let mut fab = fabric(mode, 2, 2, &template);
            for (i, snap) in snaps.iter().enumerate() {
                let o = fab.publish(snap).unwrap();
                assert_eq!(o.seq, i as u64 + 1);
                assert_eq!(o.delivered, 4, "{mode:?}");
                assert_eq!(o.dropped, 0);
                assert_eq!(o.max_skew, 0, "{mode:?}");
            }
            assert_eq!(fab.converge().unwrap(), 0);
            let reference = fab.reference().unwrap().pool.weights.clone();
            for rep in fab.replicas() {
                assert_eq!(rep.seq(), fab.head());
                assert_eq!(
                    rep.model().pool.weights,
                    reference,
                    "{mode:?} replica {:?} diverged",
                    rep.id
                );
            }
            let m = fab.metrics();
            assert_eq!(m.rounds, 3);
            assert_eq!(m.converged_rounds, 3);
            assert_eq!(m.drops(), 0);
            // auto strategy on 2-replica DCs = tree: one inter shipment
            // per DC per round
            assert_eq!(
                m.inter.iter().map(|l| l.messages).sum::<u64>(),
                2 * 3,
                "{mode:?}"
            );
        }
    }

    #[test]
    fn forced_drop_triggers_catchup_in_chained_modes() {
        for mode in [UpdateMode::PatchOnly, UpdateMode::QuantPatch] {
            let (template, snaps) = trained_snapshots(3, 250);
            let mut fab = fabric(mode, 1, 2, &template);
            fab.publish(&snaps[0]).unwrap();
            // lose round 2's single inter shipment: the whole DC tree
            // misses seq 2
            fab.force_drops(1);
            let o2 = fab.publish(&snaps[1]).unwrap();
            assert_eq!(o2.dropped, 2, "{mode:?}");
            assert_eq!(o2.max_skew, 1, "{mode:?}");
            // round 3 arrives: the head replica hits a gap and the
            // catch-up protocol replays the missed link
            let o3 = fab.publish(&snaps[2]).unwrap();
            assert_eq!(o3.max_skew, 0, "{mode:?}");
            assert!(o3.replays + o3.resyncs >= 1, "{mode:?}");
            let reference = fab.reference().unwrap().pool.weights.clone();
            for rep in fab.replicas() {
                assert_eq!(rep.model().pool.weights, reference, "{mode:?}");
            }
        }
    }

    #[test]
    fn full_file_modes_self_heal_without_catchup() {
        // raw/quant updates are self-contained: a dropped round needs
        // no protocol, the next delivery skips ahead
        let (template, snaps) = trained_snapshots(3, 250);
        let mut fab = fabric(UpdateMode::Raw, 1, 2, &template);
        fab.publish(&snaps[0]).unwrap();
        fab.force_drops(1);
        let o2 = fab.publish(&snaps[1]).unwrap();
        assert_eq!(o2.max_skew, 1);
        let o3 = fab.publish(&snaps[2]).unwrap();
        assert_eq!(o3.max_skew, 0);
        assert_eq!(o3.replays + o3.resyncs, 0);
        assert_eq!(fab.converge().unwrap(), 0);
    }

    #[test]
    fn max_chain_zero_forces_resync() {
        let (template, snaps) = trained_snapshots(3, 250);
        let topo = Topology::uniform(1, 2, LinkSpec::wan(), LinkSpec::lan());
        let mut cfg = FleetConfig::new(topo, UpdateMode::QuantPatch);
        cfg.max_chain = 0;
        let mut fab = FleetFabric::new(cfg, &template);
        fab.publish(&snaps[0]).unwrap();
        fab.force_drops(1);
        fab.publish(&snaps[1]).unwrap();
        let o3 = fab.publish(&snaps[2]).unwrap();
        assert_eq!(o3.replays, 0);
        assert!(o3.resyncs >= 1);
        let m = fab.metrics();
        assert_eq!(m.replays, 0);
        assert!(m.resyncs >= 1);
    }

    #[test]
    fn converge_pulls_final_round_stragglers() {
        let (template, snaps) = trained_snapshots(2, 250);
        let mut fab = fabric(UpdateMode::QuantPatch, 1, 2, &template);
        fab.publish(&snaps[0]).unwrap();
        fab.force_drops(1); // final round's only inter shipment lost
        let o = fab.publish(&snaps[1]).unwrap();
        assert_eq!(o.dropped, 2);
        assert_eq!(fab.converge().unwrap(), 2);
        let reference = fab.reference().unwrap().pool.weights.clone();
        for rep in fab.replicas() {
            assert_eq!(rep.seq(), 2);
            assert_eq!(rep.model().pool.weights, reference);
        }
        let m = fab.metrics();
        assert!(m.replays + m.resyncs >= 1);
        assert_eq!(m.max_version_skew, 1);
    }

    #[test]
    fn star_and_tree_byte_accounting() {
        let (template, snaps) = trained_snapshots(2, 250);
        for (strategy, inter_per_round, intra_per_round) in [
            (Strategy::Star, 3usize, 0usize),
            (Strategy::Tree, 1, 2),
        ] {
            let topo = Topology::uniform(1, 3, LinkSpec::wan(), LinkSpec::lan());
            let mut cfg = FleetConfig::new(topo, UpdateMode::Raw);
            cfg.strategy = strategy;
            let mut fab = FleetFabric::new(cfg, &template);
            let mut expect_inter = 0u64;
            let mut expect_intra = 0u64;
            for snap in &snaps {
                let o = fab.publish(snap).unwrap();
                expect_inter += (o.update_bytes * inter_per_round) as u64;
                expect_intra += (o.update_bytes * intra_per_round) as u64;
            }
            let m = fab.metrics();
            assert_eq!(m.inter_bytes(), expect_inter, "{strategy:?}");
            assert_eq!(m.intra_bytes(), expect_intra, "{strategy:?}");
        }
    }

    #[test]
    fn log_compaction_keeps_only_the_replayable_window() {
        // non-chained modes never replay: one retained payload slot
        let (template, snaps) = trained_snapshots(3, 250);
        let mut fab = fabric(UpdateMode::Raw, 1, 1, &template);
        for snap in &snaps {
            fab.publish(snap).unwrap();
        }
        assert_eq!(fab.log.len(), 3, "one slot per seq survives");
        let retained = fab.log.iter().filter(|u| !u.bytes.is_empty()).count();
        assert_eq!(retained, 1);

        // chained modes keep the max_chain newest payloads
        let (template, snaps) = trained_snapshots(4, 250);
        let topo = Topology::uniform(1, 1, LinkSpec::wan(), LinkSpec::lan());
        let mut cfg = FleetConfig::new(topo, UpdateMode::QuantPatch);
        cfg.max_chain = 2;
        let mut fab = FleetFabric::new(cfg, &template);
        for snap in &snaps {
            fab.publish(snap).unwrap();
        }
        let retained = fab.log.iter().filter(|u| !u.bytes.is_empty()).count();
        assert_eq!(retained, 2);
        // the blanked prefix is exactly the oldest entries
        assert!(fab.log[0].bytes.is_empty() && fab.log[1].bytes.is_empty());
    }

    #[test]
    fn lag_includes_tree_second_hop() {
        let (template, snaps) = trained_snapshots(1, 250);
        let topo = Topology::uniform(1, 2, LinkSpec::wan(), LinkSpec::lan());
        let mut cfg = FleetConfig::new(topo, UpdateMode::Raw);
        cfg.strategy = Strategy::Tree;
        let mut fab = FleetFabric::new(cfg, &template);
        fab.publish(&snaps[0]).unwrap();
        let m = fab.metrics();
        // replica 1 rides head's WAN hop plus its own LAN hop
        assert!(m.lag[1].last_seconds > m.lag[0].last_seconds);
    }
}
