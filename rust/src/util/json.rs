//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Parses the artifact `manifest.json` / `golden.json` written by the
//! python compile path and serializes benchmark/metric reports.  Full
//! JSON except: no `\u` surrogate-pair validation beyond basic decoding,
//! numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access that tolerates wrong shapes by returning Null.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Index into an array, Null when out of range.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    /// Convenience: array of f64.
    pub fn f64_vec(&self) -> Vec<f64> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_f64).collect())
            .unwrap_or_default()
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (`value.to_string()` via the blanket
/// `ToString` impl).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for emitting reports.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

/// Why a document failed to parse (position = byte offset).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JsonError {
    /// Input ended mid-value.
    Eof,
    /// A specific byte was required (`{`, `"`, `:`, ...).
    Expected { what: char, pos: usize },
    /// A well-formed value was followed by more non-whitespace bytes.
    Trailing { pos: usize },
    /// `true`/`false`/`null` misspelled.
    BadLiteral { pos: usize },
    /// Number span did not parse as f64.
    BadNumber { pos: usize },
    /// String ran off the end of the input.
    UnterminatedString,
    /// Unknown or truncated `\` escape.
    BadEscape { pos: usize },
    /// Raw string bytes were not valid UTF-8.
    InvalidUtf8 { pos: usize },
    /// Expected `,` or the closing bracket of an array/object.
    ExpectedSep { close: char, pos: usize },
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof => write!(f, "unexpected end of input"),
            JsonError::Expected { what, pos } => {
                write!(f, "expected '{what}' at byte {pos}")
            }
            JsonError::Trailing { pos } => write!(f, "trailing data at byte {pos}"),
            JsonError::BadLiteral { pos } => write!(f, "bad literal at byte {pos}"),
            JsonError::BadNumber { pos } => write!(f, "bad number at byte {pos}"),
            JsonError::UnterminatedString => write!(f, "unterminated string"),
            JsonError::BadEscape { pos } => write!(f, "bad escape at byte {pos}"),
            JsonError::InvalidUtf8 { pos } => {
                write!(f, "invalid utf-8 in string at byte {pos}")
            }
            JsonError::ExpectedSep { close, pos } => {
                write!(f, "expected ',' or '{close}' at byte {pos}")
            }
        }
    }
}

impl std::error::Error for JsonError {}

/// CLI shim: `fn main` paths print errors as strings.
impl From<JsonError> for String {
    fn from(e: JsonError) -> String {
        e.to_string()
    }
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let b = input.as_bytes();
    let mut p = Parser { b, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != b.len() {
        return Err(JsonError::Trailing { pos: p.pos });
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(JsonError::Expected { what: c as char, pos: self.pos })
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err(JsonError::Eof),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(JsonError::BadLiteral { pos: self.pos })
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        // the span is ASCII digits/signs only, scanned byte by byte above
        let text = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| JsonError::BadNumber { pos: start })?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::BadNumber { pos: start })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError::UnterminatedString),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    let esc_pos = self.pos;
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or(JsonError::BadEscape { pos: esc_pos })?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| {
                                    JsonError::BadEscape { pos: esc_pos }
                                })?,
                                16,
                            )
                            .map_err(|_| JsonError::BadEscape { pos: esc_pos })?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(JsonError::BadEscape { pos: esc_pos }),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| JsonError::InvalidUtf8 { pos: start })?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(JsonError::ExpectedSep { close: ']', pos: self.pos }),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(JsonError::ExpectedSep { close: '}', pos: self.pos }),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"name":"x \"y\"","ok":true}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn f64_vec_helper() {
        let v = parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.f64_vec(), vec![1.0, 2.0, 3.5]);
    }

    #[test]
    fn builders() {
        let j = obj(vec![("x", num(1.0)), ("y", s("z")), ("a", arr(vec![num(2.0)]))]);
        assert_eq!(j.to_string(), r#"{"a":[2],"x":1,"y":"z"}"#);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        if let Ok(text) = std::fs::read_to_string(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"),
        ) {
            let m = parse(&text).unwrap();
            assert!(m.get("artifacts").as_arr().unwrap().len() >= 2);
        }
    }
}
