//! `fw` — the launcher binary for the Fwumious reproduction.
//!
//! Wires the library's subsystems into operator-facing subcommands:
//! training (with Hogwild + prefetch), serving (context cache + SIMD),
//! AutoML sweeps, quantization/patching utilities, and the PJRT
//! artifact runner.  See `fw help`.

use std::path::PathBuf;
use std::sync::Arc;

use fwumious::baselines::FwModel;
use fwumious::cli::{Args, USAGE};
use fwumious::config::{ModelConfig, ServeConfig, ShedPolicy};
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::model::regressor::Regressor;
use fwumious::model::{io, Workspace};
use fwumious::patch::{apply_chain, make_patch, Compression, Patch};
use fwumious::quant;
use fwumious::serve::router::Router;
use fwumious::serve::server::ServingEngine;
use fwumious::serve::trace::TraceGenerator;
use fwumious::serve::{ModelHandle, ServeError};
use fwumious::train::warmup::{warmup, WarmupConfig};
use fwumious::util::timer::fmt_duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn dataset(name: &str) -> Result<DatasetSpec, String> {
    Ok(match name {
        "criteo" => DatasetSpec::criteo_like(),
        "avazu" => DatasetSpec::avazu_like(),
        "kdd" => DatasetSpec::kdd_like(),
        "tiny" => DatasetSpec::tiny(),
        other => return Err(format!("unknown dataset '{other}'")),
    })
}

fn run(raw: Vec<String>) -> Result<(), String> {
    let args = Args::parse(raw)?;
    match args.subcommand.as_str() {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "deploy" => cmd_deploy(&args),
        "fleet" => cmd_fleet(&args),
        "obs" => cmd_obs(&args),
        "automl" => cmd_automl(&args),
        "quantize" => cmd_quantize(&args),
        "patch" => cmd_patch(&args),
        "apply" => cmd_apply(&args),
        "pjrt" => cmd_pjrt(&args),
        "audit" => cmd_audit(&args),
        "bench" => {
            println!("run `cargo bench` — one harness per paper table/figure");
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'")),
    }
}

/// `fw audit` — run the correctness-invariant linter over the repo and
/// exit nonzero on findings (the CI lint job runs `fw audit --json`).
fn cmd_audit(args: &Args) -> Result<(), String> {
    use fwumious::audit::{self, Allowlist};
    let root = match args.flag("root") {
        Some(r) => PathBuf::from(r),
        None => {
            // walk up from the working directory to the first ancestor
            // that holds one of the scan roots, so `fw audit` works
            // from anywhere inside the checkout
            let mut dir = std::env::current_dir().map_err(|e| e.to_string())?;
            loop {
                if audit::SCAN_DIRS.iter().any(|s| dir.join(s).is_dir()) {
                    break dir;
                }
                if !dir.pop() {
                    return Err("cannot locate the repo root; pass --root DIR".into());
                }
            }
        }
    };
    let allow_path = match args.flag("allowlist") {
        Some(p) => PathBuf::from(p),
        None => root.join("audit-allow.txt"),
    };
    let allow = match std::fs::read_to_string(&allow_path) {
        Ok(text) => Allowlist::parse(&text).map_err(|e| e.to_string())?,
        // the default allowlist is optional; an explicit one must exist
        Err(_) if args.flag("allowlist").is_none() => Allowlist::default(),
        Err(e) => return Err(format!("cannot read {}: {e}", allow_path.display())),
    };
    let report = audit::run(&root, &allow).map_err(|e| e.to_string())?;
    if args.has("json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_human());
    }
    if !report.clean() {
        std::process::exit(1);
    }
    Ok(())
}

fn model_cfg_from_args(args: &Args, spec: &DatasetSpec) -> Result<ModelConfig, String> {
    let bits = args.usize_flag("bits", 18)?;
    let k = args.usize_flag("k", 4)?;
    let fields = spec.fields();
    let cfg = match args.flag_or("arch", "deepffm").as_str() {
        "linear" => ModelConfig::linear(fields, 1 << bits),
        "ffm" => ModelConfig::ffm(fields, k, 1 << bits),
        "deepffm" => {
            let hidden: Vec<usize> = args
                .flag_or("hidden", "16")
                .split(',')
                .map(|t| t.parse().map_err(|_| "bad --hidden".to_string()))
                .collect::<Result<_, _>>()?;
            ModelConfig::deep_ffm(fields, k, 1 << bits, &hidden)
        }
        other => return Err(format!("unknown arch '{other}'")),
    };
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let spec = dataset(&args.flag_or("dataset", "criteo"))?;
    let examples = args.usize_flag("examples", 200_000)?;
    let threads = args.usize_flag("threads", 1)?;
    let prefetch = args.usize_flag("prefetch", 4)?;
    let seed = args.usize_flag("seed", 42)? as u64;
    let cfg = model_cfg_from_args(args, &spec)?;
    let stream = SyntheticStream::with_buckets(spec.clone(), seed, cfg.buckets);
    println!(
        "training {:?} on {} ({} fields), {} examples, {} thread(s), prefetch depth {}",
        cfg.arch,
        spec.name,
        spec.fields(),
        examples,
        threads,
        prefetch
    );
    let mut reg = Regressor::new(&cfg);
    let report = warmup(
        &mut reg,
        stream,
        WarmupConfig {
            chunk_size: 8192,
            prefetch_depth: prefetch,
            threads,
            total: examples,
        },
    );
    let rate = report.examples as f64 / report.wall_seconds;
    println!(
        "trained {} examples in {} ({:.0} ex/s)",
        report.examples,
        fmt_duration(report.wall_seconds),
        rate
    );
    // held-out eval on fresh data
    let mut eval_stream =
        SyntheticStream::with_buckets(spec, seed ^ 0xe7a1, cfg.buckets);
    let mut ws = Workspace::new();
    let test = eval_stream.take_examples(30_000);
    let mut scores = Vec::new();
    let mut labels = Vec::new();
    for ex in &test {
        scores.push(reg.predict(ex, &mut ws));
        labels.push(ex.label);
    }
    println!("held-out AUC: {:.4}", fwumious::eval::auc(&scores, &labels));
    if let Some(path) = args.flag("out") {
        io::save(&reg, &PathBuf::from(path), args.has("with-optimizer"))
            .map_err(|e| e.to_string())?;
        println!("saved model to {path}");
    }
    Ok(())
}

/// Render a registry to `path` (overwrite) or stdout.
fn emit_metrics(reg: &fwumious::obs::ObsRegistry, path: Option<&str>) {
    let text = reg.render_prometheus();
    match path {
        Some(p) => {
            if let Err(e) = std::fs::write(p, &text) {
                eprintln!("metrics write {p}: {e}");
            }
        }
        None => print!("{text}"),
    }
}

/// Build the tracer requested by `--trace-sample` / `--trace-file`
/// (a `--trace-file` alone implies 1-in-100 sampling).
fn tracer_from_args(
    args: &Args,
) -> Result<Option<fwumious::obs::RequestTracer>, String> {
    use fwumious::obs::{RequestTracer, TraceSink};
    let mut every = args.usize_flag("trace-sample", 0)? as u64;
    if every == 0 && args.flag("trace-file").is_some() {
        every = 100;
    }
    if every == 0 {
        return Ok(None);
    }
    let sink = match args.flag("trace-file") {
        Some(p) => TraceSink::file(p).map_err(|e| e.to_string())?,
        None => TraceSink::stderr(),
    };
    Ok(Some(RequestTracer::new(every, sink)))
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    use fwumious::obs::{ObsOptions, ObsRegistry};
    use std::sync::atomic::{AtomicBool, Ordering};

    let workers = args.usize_flag("workers", 4)?;
    let requests = args.usize_flag("requests", 100_000)?;
    let fanout = args.usize_flag("fanout", 8)?;
    let metrics_every = args.usize_flag("metrics-every", 0)? as u64;
    let metrics_file = args.flag("metrics-file").map(|s| s.to_string());
    let tracer = tracer_from_args(args)?;
    // --force-isa clamps the dispatch rung (down-only: a rung the CPU
    // lacks falls back to the best available); --no-simd survives as an
    // alias for --force-isa scalar.
    if let Some(rung) = args.flag("force-isa") {
        let lvl = fwumious::simd::IsaLevel::parse(rung).ok_or_else(|| {
            format!("--force-isa wants scalar|avx2|avx512, got '{rung}'")
        })?;
        fwumious::simd::force_isa(Some(lvl));
    } else if args.has("no-simd") {
        fwumious::simd::force_scalar(true);
    }
    println!("SIMD path: {}", fwumious::simd::isa_name());

    let reg = match args.flag("model") {
        Some(path) => io::load(&PathBuf::from(path)).map_err(|e| e.to_string())?,
        None => {
            // train a quick model so the command is self-contained
            let spec = dataset(&args.flag_or("dataset", "criteo"))?;
            let cfg = model_cfg_from_args(args, &spec)?;
            let mut reg = Regressor::new(&cfg);
            let stream = SyntheticStream::with_buckets(spec, 7, cfg.buckets);
            warmup(
                &mut reg,
                stream,
                WarmupConfig {
                    chunk_size: 8192,
                    prefetch_depth: 2,
                    threads: 1,
                    total: args.usize_flag("warm-examples", 50_000)?,
                },
            );
            reg
        }
    };
    let fields = reg.cfg.fields;
    let buckets = reg.cfg.buckets;
    let ctx_fields = args.usize_flag("ctx-fields", (fields / 2).max(1))?;
    let cache_entries = if args.has("no-context-cache") { 0 } else { 65_536 };

    let router = Router::new(workers);
    router.register("ctr", ModelHandle::new(reg));
    let registry = Arc::new(ObsRegistry::new());
    let mut obs = ObsOptions::with_registry(registry.clone());
    if let Some(t) = &tracer {
        obs = obs.tracer(t.clone());
    }
    let engine = ServingEngine::start_with_obs(
        router,
        ServeConfig {
            workers,
            max_batch: args.usize_flag("max-batch", 256)?,
            max_wait_us: args.usize_flag("max-wait-us", 200)? as u64,
            context_cache_entries: cache_entries,
            max_group_candidates: args.usize_flag("max-group-candidates", 1024)?,
            queue_depth: args.usize_flag("queue-depth", 4096)?,
            shed_policy: ShedPolicy::parse(&args.flag_or("shed-policy", "reject-new"))?,
            request_slo_us: args.usize_flag("slo-us", 0)? as u64,
            degraded_max_candidates: args.usize_flag("degraded-max-candidates", 16)?,
        },
        obs,
    );
    // Periodic scrape: render the registry every --metrics-every
    // seconds to --metrics-file (or stdout) until shutdown.
    let stop = Arc::new(AtomicBool::new(false));
    let dumper = (metrics_every > 0).then(|| {
        let reg = registry.clone();
        let stop = stop.clone();
        let path = metrics_file.clone();
        std::thread::spawn(move || {
            let tick = std::time::Duration::from_millis(100);
            let period = std::time::Duration::from_secs(metrics_every);
            let mut since = std::time::Duration::ZERO;
            // ordering: Relaxed — the flag only ends the dump loop;
            // the dumper is joined before the final render, so no data
            // is published through it.
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(tick);
                since += tick;
                if since >= period {
                    since = std::time::Duration::ZERO;
                    emit_metrics(&reg, path.as_deref());
                }
            }
        })
    });
    let mut gen = TraceGenerator::new(11, fields, ctx_fields, buckets, fanout);
    let t = std::time::Instant::now();
    type Reply = std::sync::mpsc::Receiver<Result<fwumious::serve::Response, ServeError>>;
    // (served, scored, unserved) — unserved covers shed and expired
    fn drain_replies(
        inflight: &mut Vec<Reply>,
        tallies: &mut (u64, u64, u64),
    ) -> Result<(), String> {
        for rx in inflight.drain(..) {
            match rx.recv().map_err(|_| "reply dropped".to_string())? {
                Ok(resp) => {
                    tallies.0 += 1;
                    tallies.1 += resp.scores.len() as u64;
                }
                Err(ServeError::Shed(_))
                | Err(ServeError::DeadlineExpired { .. }) => tallies.2 += 1,
                Err(e) => return Err(e.to_string()),
            }
        }
        Ok(())
    }
    let mut inflight: Vec<Reply> = Vec::with_capacity(1024);
    let mut tallies = (0u64, 0u64, 0u64);
    for i in 0..requests {
        match engine.submit(gen.next_request("ctr")) {
            Ok(rx) => inflight.push(rx),
            Err(ServeError::Shed(_)) => tallies.2 += 1,
            Err(e) => return Err(e.to_string()),
        }
        if inflight.len() >= 1024 || i + 1 == requests {
            drain_replies(&mut inflight, &mut tallies)?;
        }
    }
    drain_replies(&mut inflight, &mut tallies)?;
    let (served, scored, _unserved) = tallies;
    let secs = t.elapsed().as_secs_f64();
    let stats = engine.shutdown();
    // ordering: Relaxed — see the load in the dumper loop above.
    stop.store(true, Ordering::Relaxed);
    if let Some(h) = dumper {
        let _ = h.join();
    }
    if let Some(tr) = &tracer {
        tr.flush();
    }
    // Final render so a scrape file always reflects the full run (and
    // exists even when the run outpaced the first period).
    if metrics_every > 0 || metrics_file.is_some() {
        emit_metrics(&registry, metrics_file.as_deref());
        if let Some(p) = &metrics_file {
            println!("metrics written to {p}");
        }
    }
    println!(
        "{requests} offered / {served} served / {scored} candidates in {} — {:.0} req/s, {:.0} preds/s",
        fmt_duration(secs),
        served as f64 / secs,
        scored as f64 / secs
    );
    println!(
        "cache hit rate {:.1}%  batches {}  groups {}  coalesced reqs {}  errors {}",
        stats.cache_hit_rate() * 100.0,
        stats.batches,
        stats.groups,
        stats.coalesced_requests,
        stats.errors
    );
    println!(
        "overload: shed {} (rejected {}, dropped-oldest {})  expired {}  \
         degraded transitions {}  level {}  queue depth {}",
        stats.shed(),
        stats.shed_rejected,
        stats.shed_dropped,
        stats.deadline_expired,
        stats.degraded_transitions,
        stats.degrade_label(),
        stats.queue_depth
    );
    if let Some(l) = &stats.latency {
        println!("latency (served only): {}", l.summary());
    }
    Ok(())
}

fn cmd_deploy(args: &Args) -> Result<(), String> {
    use fwumious::deploy::{DeployConfig, DeploymentLoop};
    use fwumious::transfer::UpdateMode;

    let spec = dataset(&args.flag_or("dataset", "criteo"))?;
    let mode = UpdateMode::parse(&args.flag_or("mode", "quantpatch"))?;
    let rounds = args.usize_flag("rounds", 10)?;
    let requests_per_round = args.usize_flag("requests", 2_000)?;
    let model_cfg = model_cfg_from_args(args, &spec)?;
    let fields = model_cfg.fields;
    let buckets = model_cfg.buckets;

    let mut cfg = DeployConfig::new(model_cfg, spec, mode);
    cfg.examples_per_round = args.usize_flag("examples", 50_000)?;
    cfg.train_threads = args.usize_flag("threads", 1)?;
    cfg.serve = ServeConfig {
        workers: args.usize_flag("workers", 4)?,
        ..Default::default()
    };
    cfg.seed = args.usize_flag("seed", 42)? as u64;

    println!(
        "deployment plane: {} over {} rounds x {} examples ({} hogwild thread(s), {} serve worker(s))",
        mode.label(),
        rounds,
        cfg.examples_per_round,
        cfg.train_threads,
        cfg.serve.workers
    );
    let mut dl = DeploymentLoop::new(cfg);
    let client = dl.client();
    let mut gen = TraceGenerator::new(11, fields, (fields / 2).max(1), buckets, 8);
    println!(
        "{:<6} {:>10} {:>8} {:>9} {:>9} {:>9} {:>8} {:>7}",
        "round", "update(B)", "%raw", "encode", "wire(s)", "lag(s)", "AUC", "hit%"
    );
    let model_name = dl.cfg.model_name.clone();
    for _ in 0..rounds {
        let r = dl.run_round()?;
        // keep serving against the freshly swapped snapshot
        let mut inflight = Vec::with_capacity(256);
        for _ in 0..requests_per_round {
            inflight.push(client.submit(gen.next_request(&model_name))?);
            if inflight.len() >= 256 {
                for rx in inflight.drain(..) {
                    rx.recv().map_err(|_| "reply dropped".to_string())??;
                }
            }
        }
        for rx in inflight.drain(..) {
            rx.recv().map_err(|_| "reply dropped".to_string())??;
        }
        let stats = dl.engine().stats();
        println!(
            "{:<6} {:>10} {:>7.2}% {:>7.0}ms {:>9.4} {:>9.4} {:>8.4} {:>6.1}%",
            r.round,
            r.update_bytes,
            r.update_bytes as f64 / r.raw_bytes as f64 * 100.0,
            r.encode_seconds * 1e3,
            r.wire_seconds,
            r.lag_seconds,
            r.holdout_auc,
            stats.cache_hit_rate() * 100.0
        );
    }
    let m = dl.metrics().clone();
    let ch = dl.channel().clone();
    drop(client);
    let stats = dl.shutdown();
    println!(
        "\nshipped {:.2} MB over {} rounds (raw would be {:.2} MB) — {:.1}x bandwidth saving, mean publish lag {:.3}s",
        ch.total_bytes as f64 / 1e6,
        m.rounds,
        m.raw_bytes_total as f64 / 1e6,
        m.bandwidth_saving(),
        m.mean_lag_seconds()
    );
    println!(
        "served {} requests / {} candidates, {} errors, cache hit rate {:.1}%",
        stats.requests,
        stats.candidates,
        stats.errors,
        stats.cache_hit_rate() * 100.0
    );
    if let Some(l) = &stats.latency {
        println!("latency: {}", l.summary());
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<(), String> {
    use fwumious::fleet::{plan, FleetConfig, FleetFabric, LinkSpec, Strategy, Topology};
    use fwumious::train::hogwild::{train_chunk, HogwildConfig};
    use fwumious::transfer::UpdateMode;

    if args.has("chaos") {
        return cmd_fleet_chaos(args);
    }

    let spec = dataset(&args.flag_or("dataset", "criteo"))?;
    let mode = UpdateMode::parse(&args.flag_or("mode", "quantpatch"))?;
    let strategy = Strategy::parse(&args.flag_or("strategy", "auto"))?;
    let dcs = args.usize_flag("dcs", 3)?;
    let replicas = args.usize_flag("replicas", 2)?;
    let rounds = args.usize_flag("rounds", 8)?;
    let per_round = args.usize_flag("examples", 20_000)?;
    let threads = args.usize_flag("threads", 1)?;
    let loss = args.f64_flag("loss", 0.0)?;
    if !(0.0..=1.0).contains(&loss) {
        return Err(format!("--loss must be a probability in [0, 1], got {loss}"));
    }
    let seed = args.usize_flag("seed", 42)? as u64;
    let model_cfg = model_cfg_from_args(args, &spec)?;

    let topo = Topology::uniform(
        dcs,
        replicas,
        LinkSpec::wan().with_loss(loss),
        LinkSpec::lan(),
    );
    let mut fcfg = FleetConfig::new(topo, mode);
    fcfg.strategy = strategy;
    fcfg.seed = seed;
    let mut trainer = Regressor::new(&model_cfg);
    let mut stream =
        SyntheticStream::with_buckets(spec, seed, model_cfg.buckets);
    let mut fabric = FleetFabric::new(fcfg, &trainer);

    println!(
        "fleet: {} DCs x {} replicas, {} route, {} over {} rounds x {} examples (loss {:.0}%)",
        dcs,
        replicas,
        strategy.label(),
        mode.label(),
        rounds,
        per_round,
        loss * 100.0
    );
    println!(
        "{:<6} {:>10} {:>7} {:>9} {:>8} {:>8} {:>8} {:>6}",
        "seq", "update(B)", "%raw", "delivered", "dropped", "replays", "resyncs", "skew"
    );
    let mut last_update_bytes = 0usize;
    for _ in 0..rounds {
        let chunk = stream.take_examples(per_round);
        train_chunk(
            &mut trainer,
            &chunk,
            HogwildConfig { threads: threads.max(1) },
            2_000,
        );
        let o = fabric.publish(&trainer)?;
        println!(
            "{:<6} {:>10} {:>6.2}% {:>9} {:>8} {:>8} {:>8} {:>6}",
            o.seq,
            o.update_bytes,
            o.update_bytes as f64 / o.raw_bytes.max(1) as f64 * 100.0,
            o.delivered,
            o.dropped,
            o.replays,
            o.resyncs,
            o.max_skew
        );
        last_update_bytes = o.update_bytes;
    }
    let fixed = fabric.converge()?;
    let m = fabric.metrics();
    println!(
        "\nconverged: every replica at seq {} ({} needed the final catch-up)",
        fabric.head(),
        fixed
    );
    println!(
        "inter-DC {:.2} MB, intra-DC {:.2} MB, {} drops, {} replays, {} resyncs, max skew {}, mean publish lag {:.3}s",
        m.inter_bytes() as f64 / 1e6,
        m.intra_bytes() as f64 / 1e6,
        m.drops(),
        m.replays,
        m.resyncs,
        m.max_version_skew,
        m.mean_lag_seconds()
    );
    for (dc, (i, x)) in m.inter.iter().zip(&m.intra).enumerate() {
        println!(
            "  dc{dc}: inter {:>10} B ({} msgs, {} drops)   intra {:>10} B ({} msgs)",
            i.bytes, i.messages, i.drops, x.bytes, x.messages
        );
    }
    // what the road not taken would have billed
    let star = plan(fabric.topology(), Strategy::Star);
    let tree = plan(fabric.topology(), Strategy::Tree);
    println!(
        "planner (steady-state {} B/update): star {} B vs tree {} B inter-DC per round",
        last_update_bytes,
        star.predicted_inter_bytes(fabric.topology(), last_update_bytes),
        tree.predicted_inter_bytes(fabric.topology(), last_update_bytes)
    );
    Ok(())
}

/// `fw fleet --chaos`: the seed-reproducible fault-injection soak.
/// Every run prints `chaos seed: 0x...`; pass that seed back via
/// `--seed` to replay the identical fault schedule.
fn cmd_fleet_chaos(args: &Args) -> Result<(), String> {
    use fwumious::fleet::chaos::{run_chaos_soak, ChaosConfig};
    use fwumious::transfer::UpdateMode;

    let mode = UpdateMode::parse(&args.flag_or("mode", "quantpatch"))?;
    let seed = args.usize_flag("seed", 42)? as u64;
    let mut ccfg = if args.has("smoke") {
        ChaosConfig::smoke(mode, seed)
    } else {
        ChaosConfig::full(mode, seed)
    };
    if args.flag("rounds").is_some() {
        ccfg.rounds = args.usize_flag("rounds", ccfg.rounds)?;
        if ccfg.rounds < 8 {
            return Err(format!(
                "--chaos needs --rounds >= 8 (fault-schedule quarters), got {}",
                ccfg.rounds
            ));
        }
    }
    if args.flag("examples").is_some() {
        ccfg.examples_per_round =
            args.usize_flag("examples", ccfg.examples_per_round)?;
    }
    if args.flag("threads").is_some() {
        ccfg.train_threads = args.usize_flag("threads", ccfg.train_threads)?;
    }

    println!(
        "chaos soak: {} DCs x {} replicas, {} over {} rounds x {} examples",
        ccfg.dcs,
        ccfg.replicas_per_dc,
        mode.label(),
        ccfg.rounds,
        ccfg.examples_per_round
    );
    let report = run_chaos_soak(ccfg);
    let f = &report.faults;
    println!(
        "faults injected: {} stalls, {} partitions, {} replica restarts, {} fabric restores",
        f.stalls, f.partitions, f.replica_restarts, f.fabric_restores
    );
    println!(
        "traffic: {} probes checked, {} torn, {} routed around unhealthy replicas, {} skipped mid-restart",
        report.probe_checks,
        report.torn_responses,
        report.routed_around,
        report.probe_errors
    );
    println!(
        "recovery: {} health transitions, {} publish retries, {} replay timings, {} caught up at converge",
        report.health_transitions,
        report.metrics.retries,
        report.recovery_samples,
        report.caught_up_at_converge
    );
    report.assert_healthy();
    println!(
        "all invariants held: zero torn responses, bit-identical convergence \
         (replay with: fw fleet --chaos --seed {})",
        report.seed
    );
    Ok(())
}

fn cmd_obs(args: &Args) -> Result<(), String> {
    use fwumious::deploy::{DeployConfig, DeploymentLoop};
    use fwumious::fleet::{FleetConfig, FleetFabric, LinkSpec, Topology};
    use fwumious::obs::{ObsOptions, ObsRegistry};
    use fwumious::train::hogwild::{train_chunk, HogwildConfig};
    use fwumious::transfer::UpdateMode;

    // Validator mode: `fw obs --check-file metrics.prom` parses a
    // scrape written by `fw serve --metrics-file` (used by CI).
    if let Some(path) = args.flag("check-file") {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        fwumious::testutil::check_prometheus_text(&text)
            .map_err(|e| format!("{path}: {e}"))?;
        let samples = text
            .lines()
            .filter(|l| !l.starts_with('#') && !l.trim().is_empty())
            .count();
        println!("{path}: well-formed Prometheus text ({samples} samples)");
        return Ok(());
    }

    // Snapshot mode: run the whole system small — deploy rounds
    // (train → encode → ship → swap) with live traffic, plus a fleet
    // publish — all recording into ONE registry, then render it.
    let rounds = args.usize_flag("rounds", 2)?;
    let per_round = args.usize_flag("examples", 2_000)?;
    let spec = dataset(&args.flag_or("dataset", "tiny"))?;
    let model_cfg = model_cfg_from_args(args, &spec)?;
    let fields = model_cfg.fields;
    let buckets = model_cfg.buckets;

    let registry = Arc::new(ObsRegistry::new());
    let tracer = tracer_from_args(args)?;
    let mut obs = ObsOptions::with_registry(registry.clone());
    if let Some(t) = &tracer {
        obs = obs.tracer(t.clone());
    }

    let mut dcfg =
        DeployConfig::new(model_cfg.clone(), spec.clone(), UpdateMode::QuantPatch);
    dcfg.examples_per_round = per_round;
    dcfg.holdout_examples = 1_000;
    let mut dl = DeploymentLoop::with_obs(dcfg, obs);
    let client = dl.client();
    let mut gen = TraceGenerator::new(11, fields, (fields / 2).max(1), buckets, 8);
    for _ in 0..rounds {
        dl.run_round()?;
        let mut inflight = Vec::with_capacity(256);
        for _ in 0..200 {
            inflight.push(client.submit(gen.next_request("ctr"))?);
        }
        for rx in inflight {
            rx.recv().map_err(|_| "reply dropped".to_string())??;
        }
    }

    let topo = Topology::uniform(2, 2, LinkSpec::wan(), LinkSpec::lan());
    let mut fcfg = FleetConfig::new(topo, UpdateMode::QuantPatch);
    fcfg.seed = 7;
    let mut trainer = Regressor::new(&model_cfg);
    let mut stream = SyntheticStream::with_buckets(spec, 7, model_cfg.buckets);
    let mut fabric = FleetFabric::new(fcfg, &trainer);
    if let Some(t) = &tracer {
        fabric.set_tracer(t.clone());
    }
    for _ in 0..rounds {
        let chunk = stream.take_examples(per_round.min(1_000));
        let stats =
            train_chunk(&mut trainer, &chunk, HogwildConfig { threads: 1 }, 500);
        stats.export_to(&registry);
        fabric.publish(&trainer)?;
    }
    fabric.metrics().export_to(&registry);

    drop(client);
    let _ = dl.shutdown();
    if let Some(t) = &tracer {
        t.flush();
    }
    let text = registry.render_prometheus();
    fwumious::testutil::check_prometheus_text(&text)
        .map_err(|e| format!("render self-check: {e}"))?;
    match args.flag("out") {
        Some(p) => {
            std::fs::write(p, &text).map_err(|e| e.to_string())?;
            println!("wrote {} bytes of metrics to {p}", text.len());
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_automl(args: &Args) -> Result<(), String> {
    use fwumious::automl::{pooled_stats, random_search, SearchSpace};
    let spec = dataset(&args.flag_or("dataset", "tiny"))?;
    let examples = args.usize_flag("examples", 50_000)?;
    let configs = args.usize_flag("configs", 16)?;
    let threads = args.usize_flag("threads", 4)?;
    let buckets = 1u32 << args.usize_flag("bits", 14)?;
    let fields = spec.fields();
    let mut s = SyntheticStream::with_buckets(spec.clone(), 5, buckets);
    let train = Arc::new(s.take_examples(examples));
    let test = Arc::new(s.take_examples(examples / 5));
    println!(
        "automl: {} configs × {} examples on {} ({} threads)",
        configs, examples, spec.name, threads
    );
    let results = random_search(
        &SearchSpace::default(),
        configs,
        threads,
        99,
        train,
        test,
        args.usize_flag("window", 10_000)?,
        |c| {
            let mut cfg = ModelConfig::deep_ffm(fields, c.latent_dim, buckets, &c.hidden);
            cfg.lr = c.lr;
            cfg.ffm_lr = c.ffm_lr;
            cfg.nn_lr = c.nn_lr;
            cfg.power_t = c.power_t;
            cfg.l2 = c.l2;
            cfg.seed = c.seed;
            FwModel::new("FW-DeepFFM", Regressor::new(&cfg))
        },
    );
    println!(
        "{:<6} {:>7} {:>7} {:>8} {:>9} {:>8}",
        "id", "test", "avg", "std", "logloss", "seconds"
    );
    for r in &results {
        println!(
            "{:<6} {:>7.4} {:>7.4} {:>8.4} {:>9.4} {:>8.2}",
            r.config.id,
            r.stats.test,
            r.stats.avg,
            r.stats.std,
            r.mean_logloss,
            r.train_seconds
        );
    }
    let pooled = pooled_stats(&results);
    println!("pooled: {}", pooled.row("FW-DeepFFM"));
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<(), String> {
    let input = args.flag("in").ok_or("--in required")?;
    let output = args.flag("out").ok_or("--out required")?;
    let reg = io::load(&PathBuf::from(input)).map_err(|e| e.to_string())?;
    let t = std::time::Instant::now();
    let bytes = quant::quantize_to_bytes(&reg.pool.weights, 2, 2);
    let secs = t.elapsed().as_secs_f64();
    std::fs::write(output, &bytes).map_err(|e| e.to_string())?;
    println!(
        "quantized {} weights ({} -> {} bytes, {:.1}%) in {}",
        reg.pool.weights.len(),
        reg.pool.weights.len() * 4,
        bytes.len(),
        bytes.len() as f64 / (reg.pool.weights.len() * 4) as f64 * 100.0,
        fmt_duration(secs)
    );
    Ok(())
}

fn cmd_patch(args: &Args) -> Result<(), String> {
    let old = std::fs::read(args.flag("old").ok_or("--old required")?)
        .map_err(|e| e.to_string())?;
    let new = std::fs::read(args.flag("new").ok_or("--new required")?)
        .map_err(|e| e.to_string())?;
    let out = args.flag("out").ok_or("--out required")?;
    let t = std::time::Instant::now();
    let p = make_patch(&old, &new, Compression::Lz);
    std::fs::write(out, p.to_wire()).map_err(|e| e.to_string())?;
    println!(
        "patch {} bytes ({:.2}% of new file) in {}",
        p.wire_bytes(),
        p.wire_bytes() as f64 / new.len().max(1) as f64 * 100.0,
        fmt_duration(t.elapsed().as_secs_f64())
    );
    Ok(())
}

fn cmd_apply(args: &Args) -> Result<(), String> {
    let old = std::fs::read(args.flag("old").ok_or("--old required")?)
        .map_err(|e| e.to_string())?;
    // --patch takes one file or a comma-separated delta chain, applied
    // in order (the offline twin of the fleet's catch-up replay)
    let spec = args.flag("patch").ok_or("--patch required")?;
    let out = args.flag("out").ok_or("--out required")?;
    let mut chain = Vec::new();
    for path in spec.split(',').filter(|p| !p.is_empty()) {
        let pbytes = std::fs::read(path).map_err(|e| format!("{path}: {e}"))?;
        chain.push(Patch::from_wire(&pbytes)?);
    }
    if chain.is_empty() {
        return Err("--patch names no patch files".into());
    }
    let new = apply_chain(&old, &chain)?;
    std::fs::write(out, &new).map_err(|e| e.to_string())?;
    println!("applied {} patch(es) -> {} bytes", chain.len(), new.len());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pjrt(_args: &Args) -> Result<(), String> {
    Err("this binary was built without the `pjrt` feature; rebuild with \
         `--features pjrt` (requires the xla crate, see rust/Cargo.toml)"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_pjrt(args: &Args) -> Result<(), String> {
    use fwumious::runtime::{default_artifact_dir, load_goldens, ArgValue, Manifest, PjrtEngine};
    let dir = args
        .flag("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let manifest = Manifest::load(&dir).map_err(|e| e.to_string())?;
    let goldens = load_goldens(&dir).map_err(|e| e.to_string())?;
    let engine = PjrtEngine::cpu().map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", engine.platform());
    for g in &goldens {
        let compiled = engine.compile(&manifest, &g.name).map_err(|e| e.to_string())?;
        let mut argv = vec![ArgValue::F32(g.lr_table.clone()), ArgValue::F32(g.ffm_table.clone())];
        for m in &g.mlp {
            argv.push(ArgValue::F32(m.clone()));
        }
        argv.push(ArgValue::I32(g.idx.clone()));
        argv.push(ArgValue::F32(g.vals.clone()));
        let probs = compiled.run(&argv).map_err(|e| e.to_string())?;
        let max_err = probs
            .iter()
            .zip(&g.probs)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("{}: max |pjrt - golden| = {max_err:.2e}", g.name);
        if max_err > 1e-4 {
            return Err(format!("{}: PJRT output deviates from golden", g.name));
        }
    }
    println!("all artifacts reproduce golden vectors");
    Ok(())
}
