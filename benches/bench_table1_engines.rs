//! Table 1 — stability analysis and overall performance.
//!
//! Reproduces the paper's benchmark protocol on the synthetic dataset
//! substitutes: each engine (VW-linear, VW-mlp, FW-FFM, FW-DeepFFM,
//! DCNv2) is trained single-pass over several configurations; rolling
//! window AUCs (window = 30k in the paper, scaled here) are pooled per
//! engine and summarized as avg/median/max/std/min plus held-out test
//! AUC.  Expected shape: FW engines above the VW ones with a LOWER std
//! (stability) once enough data is seen; DCNv2 competitive; VW-mlp no
//! better than VW-linear.  Runtimes: FW-DeepFFM in the same band as
//! VW-linear; DCNv2 notably slower.

use std::sync::Arc;

use fwumious::automl::{evaluate_model, pooled_stats, CandidateConfig, RunResult};
use fwumious::baselines::dcnv2::DcnV2;
use fwumious::baselines::vw_linear::VwLinear;
use fwumious::baselines::vw_mlp::VwMlp;
use fwumious::baselines::{FwModel, OnlineModel};
use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::feature::Example;
use fwumious::model::regressor::Regressor;
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj, s};

const BUCKET_BITS: u32 = 16;
const TRAIN_N: usize = 60_000;
const TEST_N: usize = 15_000;
const WINDOW: usize = 6_000; // paper: 30k on full datasets; scaled 1:5
const CONFIGS: usize = 3;

/// Adapter: evaluate_model is generic over `M: OnlineModel`, engines
/// are built dynamically — wrap the box.
struct Boxed(Box<dyn OnlineModel>);

impl OnlineModel for Boxed {
    fn name(&self) -> &str {
        self.0.name()
    }
    fn learn(&mut self, ex: &Example) -> f32 {
        self.0.learn(ex)
    }
    fn predict(&mut self, ex: &Example) -> f32 {
        self.0.predict(ex)
    }
    fn num_weights(&self) -> usize {
        self.0.num_weights()
    }
}

fn cand(id: usize, lr: f32, k: usize, hidden: Vec<usize>, seed: u64) -> CandidateConfig {
    CandidateConfig {
        id,
        lr,
        ffm_lr: lr * 0.5,
        nn_lr: lr * 0.25,
        power_t: 0.4,
        l2: 0.0,
        latent_dim: k,
        hidden,
        seed,
    }
}

type Factory<'a> = Box<dyn Fn(&CandidateConfig) -> Box<dyn OnlineModel> + 'a>;

fn run_engine(
    train: &Arc<Vec<Example>>,
    test: &Arc<Vec<Example>>,
    make: &Factory,
) -> Vec<RunResult> {
    let lrs = [0.05f32, 0.15, 0.3];
    (0..CONFIGS)
        .map(|i| {
            let c = cand(i, lrs[i % lrs.len()], 4, vec![16], 1000 + i as u64);
            let model = Boxed(make(&c));
            evaluate_model(c, model, train, test, WINDOW)
        })
        .collect()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let buckets = 1u32 << BUCKET_BITS;
    let mut report_rows = Vec::new();
    println!("== Table 1: stability analysis (synthetic substitutes, window={WINDOW}) ==\n");
    for spec in [
        DatasetSpec::avazu_like(),
        DatasetSpec::criteo_like(),
        DatasetSpec::kdd_like(),
    ] {
        let fields = spec.fields();
        let mut s = SyntheticStream::with_buckets(spec.clone(), 11, buckets);
        let train = Arc::new(s.take_examples(TRAIN_N));
        let test = Arc::new(s.take_examples(TEST_N));
        println!("--- {} ---", spec.name);
        println!(
            "{:<12} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7}   ({} configs pooled)",
            "algo", "avg", "median", "max", "std", "min", "test", CONFIGS
        );

        let engines: Vec<(&str, Factory)> = vec![
            (
                "VW-linear",
                Box::new(move |c: &CandidateConfig| {
                    Box::new(VwLinear::new(buckets, c.lr, c.power_t)) as Box<dyn OnlineModel>
                }),
            ),
            (
                "VW-mlp",
                Box::new(move |c: &CandidateConfig| {
                    Box::new(VwMlp::new(buckets, 8, c.lr, c.power_t, c.seed))
                        as Box<dyn OnlineModel>
                }),
            ),
            (
                "FW-FFM",
                Box::new(move |c: &CandidateConfig| {
                    let mut cfg = ModelConfig::ffm(fields, c.latent_dim, buckets);
                    cfg.lr = c.lr;
                    cfg.ffm_lr = c.ffm_lr;
                    cfg.power_t = c.power_t;
                    cfg.seed = c.seed;
                    Box::new(FwModel::new("FW-FFM", Regressor::new(&cfg)))
                        as Box<dyn OnlineModel>
                }),
            ),
            (
                "FW-DeepFFM",
                Box::new(move |c: &CandidateConfig| {
                    let mut cfg =
                        ModelConfig::deep_ffm(fields, c.latent_dim, buckets, &c.hidden);
                    cfg.lr = c.lr;
                    cfg.ffm_lr = c.ffm_lr;
                    cfg.nn_lr = c.nn_lr;
                    cfg.power_t = c.power_t;
                    cfg.seed = c.seed;
                    Box::new(FwModel::new("FW-DeepFFM", Regressor::new(&cfg)))
                        as Box<dyn OnlineModel>
                }),
            ),
            (
                "DCNv2",
                Box::new(move |c: &CandidateConfig| {
                    Box::new(DcnV2::new(buckets, fields, c.latent_dim, 2, c.lr, c.seed))
                        as Box<dyn OnlineModel>
                }),
            ),
        ];

        let mut rows = Vec::new();
        for (name, make) in &engines {
            let t = std::time::Instant::now();
            let results = run_engine(&train, &test, make);
            let pooled = pooled_stats(&results);
            println!("{}", pooled.row(name));
            let secs = t.elapsed().as_secs_f64();
            report_rows.push(obj(vec![
                ("dataset", s(&spec.name)),
                ("engine", s(name)),
                ("pooled_avg_auc", num(pooled.avg)),
                ("pooled_std_auc", num(pooled.std)),
                ("test_auc", num(pooled.test)),
                ("train_eval_seconds", num(secs)),
            ]));
            rows.push((name.to_string(), secs));
        }
        println!("    runtimes (train+eval, {} configs):", CONFIGS);
        for (name, secs) in &rows {
            println!("      {name:<12} {secs:>6.2}s");
        }
        println!();
    }
    let path = bench_env::write_report(
        "table1_engines",
        smoke,
        vec![
            ("train_examples", num(TRAIN_N as f64)),
            ("test_examples", num(TEST_N as f64)),
            ("window", num(WINDOW as f64)),
            ("configs_pooled", num(CONFIGS as f64)),
            ("engines", arr(report_rows)),
        ],
    );
    println!("report -> {path}");
    println!("expected shape: FW engines above VW on pooled AUC with smaller std;");
    println!("VW-mlp ≈ VW-linear; DCNv2 competitive; FW-DeepFFM best-or-near-best test.");
}
