//! Fleet fan-out benchmark: star vs fan-out-tree bytes-on-the-wire
//! across the four §6 quantization/patching modes.
//!
//! The same trained snapshot sequence is published through two
//! otherwise identical fleets (3 DCs × 3 replicas); only the route
//! plan differs.  The tree plan must provably ship fewer inter-DC
//! bytes — the expensive edge — for every mode, trading them for
//! cheap intra-DC re-fan-out and one extra LAN hop of lag.
//!
//! Emits a machine-readable `BENCH_fleet_fanout.json` (per mode:
//! bytes/round on each edge class, lag means, tree/star ratio) so
//! future PRs can diff regressions.  `--smoke` runs a CI-sized
//! variant.

use fwumious::config::ModelConfig;
use fwumious::data::synthetic::{DatasetSpec, SyntheticStream};
use fwumious::fleet::{FleetConfig, FleetFabric, FleetMetrics, LinkSpec, Strategy, Topology};
use fwumious::model::regressor::Regressor;
use fwumious::model::Workspace;
use fwumious::transfer::UpdateMode;
use fwumious::util::bench_env;
use fwumious::util::json::{arr, num, obj, s};

struct StrategyRun {
    inter_bytes: u64,
    intra_bytes: u64,
    mean_lag_seconds: f64,
}

fn run_strategy(
    strategy: Strategy,
    mode: UpdateMode,
    dcs: usize,
    replicas: usize,
    template: &Regressor,
    snaps: &[Regressor],
) -> StrategyRun {
    let topo = Topology::uniform(dcs, replicas, LinkSpec::wan(), LinkSpec::lan());
    let mut cfg = FleetConfig::new(topo, mode);
    cfg.strategy = strategy;
    let mut fab = FleetFabric::new(cfg, template);
    for snap in snaps {
        fab.publish(snap).expect("publish");
    }
    let m: FleetMetrics = fab.metrics();
    StrategyRun {
        inter_bytes: m.inter_bytes(),
        intra_bytes: m.intra_bytes(),
        mean_lag_seconds: m.mean_lag_seconds(),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (dcs, replicas, rounds, per_round, bits) =
        if smoke { (3, 2, 3, 3_000, 14) } else { (3, 3, 6, 20_000, 18) };
    let spec = DatasetSpec::criteo_like();
    let model = ModelConfig::deep_ffm(spec.fields(), 2, 1u32 << bits, &[16]);

    // train the snapshot sequence once; every (mode, strategy) pair
    // re-publishes the identical weights
    let template = Regressor::new(&model);
    let mut reg = template.clone();
    let mut ws = Workspace::new();
    let mut stream = SyntheticStream::with_buckets(spec, 42, model.buckets);
    let mut snaps = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        for _ in 0..per_round {
            let ex = stream.next_example();
            reg.learn(&ex, &mut ws);
        }
        snaps.push(reg.clone());
    }

    println!(
        "== fleet fan-out: {} DCs x {} replicas, {} rounds x {} examples{} ==\n",
        dcs,
        replicas,
        rounds,
        per_round,
        if smoke { " (smoke)" } else { "" }
    );
    println!(
        "{:<28} {:>12} {:>12} {:>7} {:>12} {:>10}",
        "mode", "star inter", "tree inter", "ratio", "tree intra", "tree lag"
    );

    let mut mode_rows = Vec::new();
    for mode in UpdateMode::ALL {
        let star = run_strategy(Strategy::Star, mode, dcs, replicas, &template, &snaps);
        let tree = run_strategy(Strategy::Tree, mode, dcs, replicas, &template, &snaps);
        assert!(
            tree.inter_bytes < star.inter_bytes,
            "{mode:?}: tree {} must undercut star {}",
            tree.inter_bytes,
            star.inter_bytes
        );
        let ratio = tree.inter_bytes as f64 / star.inter_bytes as f64;
        println!(
            "{:<28} {:>12} {:>12} {:>6.3} {:>12} {:>9.4}s",
            mode.label(),
            star.inter_bytes,
            tree.inter_bytes,
            ratio,
            tree.intra_bytes,
            tree.mean_lag_seconds
        );
        mode_rows.push(obj(vec![
            ("mode", s(mode.label())),
            ("star_inter_bytes", num(star.inter_bytes as f64)),
            ("star_bytes_per_round", num(star.inter_bytes as f64 / rounds as f64)),
            ("star_mean_lag_seconds", num(star.mean_lag_seconds)),
            ("tree_inter_bytes", num(tree.inter_bytes as f64)),
            ("tree_intra_bytes", num(tree.intra_bytes as f64)),
            ("tree_bytes_per_round", num(tree.inter_bytes as f64 / rounds as f64)),
            ("tree_mean_lag_seconds", num(tree.mean_lag_seconds)),
            ("inter_ratio_tree_vs_star", num(ratio)),
        ]));
    }

    let path = bench_env::write_report(
        "fleet_fanout",
        smoke,
        vec![
            ("dcs", num(dcs as f64)),
            ("replicas_per_dc", num(replicas as f64)),
            ("rounds", num(rounds as f64)),
            ("examples_per_round", num(per_round as f64)),
            ("modes", arr(mode_rows)),
        ],
    );
    println!("\ntree route ships 1/{replicas} of star's inter-DC bytes per DC; report -> {path}");
}
